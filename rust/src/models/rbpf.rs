//! RBPF: mixed linear/nonlinear state-space model (Lindsten & Schön 2010)
//! with a Rao–Blackwellized particle filter via delayed sampling.
//!
//! Per particle: a nonlinear scalar state ξ (sampled) and a 3-dimensional
//! linear substate z (marginalized as a per-particle Kalman belief — the
//! delayed-sampling automatic Rao–Blackwellization):
//!
//!   ξ_t = 0.5 ξ + 25 ξ/(1+ξ²) + 8 cos(1.2 t) + v,  v ~ N(0, q_ξ)
//!   z_t = A z_{t-1} + w,                            w ~ N(0, Q)
//!   y1_t = ξ_t²/20 + e1,  e1 ~ N(0, r_ξ)
//!   y2_t = C z_t + e2,    e2 ~ N(0, R)
//!
//! The per-generation Kalman update over the particle batch is the numeric
//! hot spot: the `step_batched` hook splits each generation into a serial
//! heap phase and a batched phase running the compiled XLA artifact (the
//! L1 Pallas kernel) or the CPU oracle — per shard-local run, so every
//! shard count takes the batched path.
//!
//! Paper scale: N = 2048, T = 500. Data: simulated (as in the paper).

use crate::heap::{Heap, Lazy};
use crate::lazy_fields;
use crate::linalg::Mat;
use crate::ppl::KalmanState;
use crate::rng::{normal_lpdf, Pcg64};
use crate::runtime::{batch_kalman_cpu, KalmanParams, DZ};
use crate::smc::{particle_rng, SmcModel, StepCtx};

const Q_XI: f64 = 0.5;
const R_XI: f64 = 0.7;

/// One generation of a particle's history (chained backwards).
#[derive(Clone)]
pub struct RbpfState {
    /// Nonlinear substate ξ (sampled per particle).
    pub xi: f64,
    /// Marginalized linear substate belief.
    pub kalman: KalmanState,
    /// Previous generation (the history chain).
    pub prev: Lazy<RbpfState>,
}
lazy_fields!(RbpfState: prev);

/// The Rao-Blackwellized PF model (Lindsten & Schön 2010 mixed SSM).
///
/// `Clone` supports what-if serving: speculative branches clone the
/// model and append hypothetical observations without disturbing the
/// live stream.
#[derive(Clone)]
pub struct Rbpf {
    /// Linear-substate parameters (shared with the compiled artifact).
    pub params: KalmanParams,
    /// Observations (y1, y2) per generation.
    pub obs: Vec<(f64, f64)>,
}

fn xi_dynamics(xi: f64, t: usize) -> f64 {
    0.5 * xi + 25.0 * xi / (1.0 + xi * xi) + 8.0 * (1.2 * t as f64).cos()
}

impl Rbpf {
    /// Simulate `t_max` observations from the model (the paper's setup).
    pub fn synthetic(t_max: usize, seed: u64) -> Self {
        let params = KalmanParams::rbpf_default();
        let mut rng = Pcg64::stream(seed, 0xDA7A);
        let mut xi = rng.gaussian(0.0, 1.0);
        let mut z = vec![0.0f64; DZ];
        let mut obs = Vec::with_capacity(t_max);
        for t in 1..=t_max {
            xi = xi_dynamics(xi, t) + rng.gaussian(0.0, Q_XI.sqrt());
            // z' = A z + w.
            let az = params.a.matmul(&Mat::col_vec(&z));
            for (d, zd) in z.iter_mut().enumerate() {
                *zd = az.at(d, 0) + rng.gaussian(0.0, params.q.at(d, d).sqrt());
            }
            let y1 = xi * xi / 20.0 + rng.gaussian(0.0, R_XI.sqrt());
            let cz: f64 = (0..DZ).map(|d| params.c.at(0, d) * z[d]).sum();
            let y2 = cz + rng.gaussian(0.0, params.r.sqrt());
            obs.push((y1, y2));
        }
        Rbpf { params, obs }
    }

    /// Default parameters and **no observations yet** — the
    /// incremental-ingest starting point for the `serve` subcommand
    /// (observations arrive via
    /// [`stream_observation`](SmcModel::stream_observation)).
    pub fn streaming() -> Self {
        Rbpf {
            params: KalmanParams::rbpf_default(),
            obs: Vec::new(),
        }
    }

    fn initial_state() -> RbpfState {
        RbpfState {
            xi: 0.0,
            kalman: KalmanState::new(vec![0.0; DZ], Mat::eye(DZ)),
            prev: Lazy::NULL,
        }
    }
}

impl SmcModel for Rbpf {
    type State = RbpfState;

    fn name(&self) -> &'static str {
        "rbpf"
    }

    fn horizon(&self) -> usize {
        self.obs.len()
    }

    fn init(&self, heap: &mut Heap, rng: &mut Pcg64) -> Lazy<RbpfState> {
        let mut s = Self::initial_state();
        s.xi = rng.gaussian(0.0, 1.0);
        heap.alloc(s)
    }

    fn step(
        &self,
        heap: &mut Heap,
        state: &mut Lazy<RbpfState>,
        t: usize,
        rng: &mut Pcg64,
        observe: bool,
    ) -> f64 {
        let (xi_prev, mut ks) = heap.read(state, |s| (s.xi, s.kalman.clone()));
        let xi = xi_dynamics(xi_prev, t) + rng.gaussian(0.0, Q_XI.sqrt());
        let (y1, y2) = if observe {
            self.obs[t - 1]
        } else {
            // Simulation: sample pseudo-observations, discard weights.
            (xi * xi / 20.0 + rng.gaussian(0.0, R_XI.sqrt()), rng.gaussian(0.0, 1.0))
        };
        ks.predict(&self.params.a, &[0.0; DZ], &self.params.q);
        let ll_z = ks.update(&self.params.c, &Mat::from_rows(&[&[self.params.r]]), &[y2]);
        let ll_xi = normal_lpdf(y1, xi * xi / 20.0, R_XI.sqrt());
        let old = *state;
        let new = heap.alloc(RbpfState {
            xi,
            kalman: ks,
            prev: old,
        });
        heap.release(old);
        *state = new;
        if observe {
            ll_xi + ll_z
        } else {
            0.0
        }
    }

    /// Batched generation: serial heap reads → batched Kalman (XLA artifact
    /// or CPU oracle, parallelized by the pool) → serial heap writes. The
    /// hook only covers inference: simulation samples pseudo-observations
    /// from the per-particle RNG stream, which is inherently scalar, so it
    /// declines (`None`) and the coordinator loops [`SmcModel::step`].
    #[allow(clippy::too_many_arguments)]
    fn step_batched(
        &self,
        heap: &mut Heap,
        states: &mut [Lazy<RbpfState>],
        t: usize,
        seed: u64,
        observe: bool,
        base: usize,
        ctx: &StepCtx,
    ) -> Option<Vec<f64>> {
        if !observe {
            return None;
        }
        let n = states.len();
        // Phase 1 (serial, heap): read previous numeric state.
        let mut xis = vec![0.0f64; n];
        let mut means = vec![0.0f64; n * DZ];
        let mut covs = vec![0.0f64; n * DZ * DZ];
        for (i, s) in states.iter_mut().enumerate() {
            heap.read(s, |st| {
                xis[i] = st.xi;
                means[i * DZ..(i + 1) * DZ].copy_from_slice(&st.kalman.mean);
                for r in 0..DZ {
                    for c in 0..DZ {
                        covs[i * DZ * DZ + r * DZ + c] = st.kalman.cov.at(r, c);
                    }
                }
            });
        }
        // Phase 2 (parallel, no heap): nonlinear propagation + y1 weights.
        let mut ll_xi = vec![0.0f64; n];
        let (y1, y2) = self.obs[t - 1];
        {
            let xis_ptr = &mut xis;
            let ll_ptr = &mut ll_xi;
            // map_indexed writes disjoint slots; compute xi' and ll.
            let xi_prev: Vec<f64> = xis_ptr.clone();
            let results: &mut Vec<(f64, f64)> = &mut vec![(0.0, 0.0); n];
            ctx.pool.map_indexed(results, |i| {
                let mut rng = particle_rng(seed, t, base + i);
                let xi = xi_dynamics(xi_prev[i], t) + rng.gaussian(0.0, Q_XI.sqrt());
                let ll = normal_lpdf(y1, xi * xi / 20.0, R_XI.sqrt());
                (xi, ll)
            });
            for i in 0..n {
                xis_ptr[i] = results[i].0;
                ll_ptr[i] = results[i].1;
            }
        }
        // Phase 3 (batched): Kalman predict+update+weight.
        let ll_z = match ctx.kalman {
            Some(bk) => bk
                .run(&mut means, &mut covs, y2)
                .expect("batched kalman artifact failed"),
            None => batch_kalman_cpu(&self.params, &mut means, &mut covs, y2),
        };
        // Phase 4 (serial, heap): extend chains.
        let mut out = Vec::with_capacity(n);
        for (i, s) in states.iter_mut().enumerate() {
            let mut cov = Mat::zeros(DZ, DZ);
            for r in 0..DZ {
                for c in 0..DZ {
                    *cov.at_mut(r, c) = covs[i * DZ * DZ + r * DZ + c];
                }
            }
            let ks = KalmanState::new(means[i * DZ..(i + 1) * DZ].to_vec(), cov);
            let old = *s;
            let label = s.label();
            let new = heap.with_context(label, |h| {
                h.alloc(RbpfState {
                    xi: xis[i],
                    kalman: ks,
                    prev: old,
                })
            });
            heap.release(old);
            *s = new;
            out.push(ll_xi[i] + ll_z[i]);
        }
        Some(out)
    }

    fn summary(&self, heap: &mut Heap, state: &mut Lazy<RbpfState>) -> f64 {
        heap.read(state, |s| s.xi + s.kalman.mean[0])
    }

    fn chain(&self, heap: &mut Heap, state: &Lazy<RbpfState>) -> Vec<Lazy<RbpfState>> {
        let mut out = vec![heap.clone_handle(state)];
        let mut cur = *state;
        loop {
            let prev = heap.read_ptr(&mut cur, |s| s.prev);
            if prev.is_null() {
                break;
            }
            out.push(heap.clone_handle(&prev));
            cur = prev;
        }
        out
    }

    /// One observation per generation: the pair `y1 y2` (both finite).
    fn stream_observation(&mut self, tokens: &[&str]) -> Result<(), String> {
        let [t1, t2] = tokens else {
            return Err(format!(
                "rbpf expects two observation values per generation (y1 y2), got {} tokens",
                tokens.len()
            ));
        };
        let y1: f64 = t1
            .parse()
            .map_err(|_| format!("rbpf observation y1 '{t1}' is not a number"))?;
        let y2: f64 = t2
            .parse()
            .map_err(|_| format!("rbpf observation y2 '{t2}' is not a number"))?;
        if !y1.is_finite() || !y2.is_finite() {
            return Err("rbpf observations must be finite".to_string());
        }
        self.obs.push((y1, y2));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Model, RunConfig, Task};
    use crate::heap::CopyMode;
    use crate::pool::ThreadPool;
    use crate::smc::{run_filter, Method};

    fn ctx(pool: &ThreadPool) -> StepCtx<'_> {
        StepCtx { pool, kalman: None, batch: true }
    }

    fn cfg(n: usize, t: usize, mode: CopyMode) -> RunConfig {
        let mut c = RunConfig::for_model(Model::Rbpf, Task::Inference, mode);
        c.n_particles = n;
        c.n_steps = t;
        c.seed = 7;
        c
    }

    #[test]
    fn synthetic_data_reproducible() {
        let a = Rbpf::synthetic(50, 1);
        let b = Rbpf::synthetic(50, 1);
        assert_eq!(a.obs, b.obs);
        let c = Rbpf::synthetic(50, 2);
        assert_ne!(a.obs, c.obs);
    }

    #[test]
    fn batched_step_equals_sequential_step() {
        // step_batched (CPU batch path) must produce bit-identical weights
        // and states to the per-particle step — the SmcModel contract.
        let model = Rbpf::synthetic(5, 3);
        let pool = ThreadPool::new(2);
        let n = 16;
        let mut heap_a = crate::heap::Heap::new(CopyMode::LazySro);
        let mut heap_b = crate::heap::Heap::new(CopyMode::LazySro);
        let mut sa: Vec<_> = (0..n)
            .map(|i| model.init(&mut heap_a, &mut particle_rng(7, 0, i)))
            .collect();
        let mut sb: Vec<_> = (0..n)
            .map(|i| model.init(&mut heap_b, &mut particle_rng(7, 0, i)))
            .collect();
        for t in 1..=5 {
            let wa = model
                .step_batched(&mut heap_a, &mut sa, t, 7, true, 0, &ctx(&pool))
                .expect("rbpf batches inference");
            let mut wb = Vec::new();
            for (i, s) in sb.iter_mut().enumerate() {
                let mut rng = particle_rng(7, t, i);
                wb.push(model.step(&mut heap_b, s, t, &mut rng, true));
            }
            for i in 0..n {
                assert_eq!(
                    wa[i].to_bits(),
                    wb[i].to_bits(),
                    "t={t} i={i}: {} vs {}",
                    wa[i],
                    wb[i]
                );
            }
            for i in 0..n {
                let a = heap_a.read(&mut sa[i], |s| (s.xi, s.kalman.mean.clone()));
                let b = heap_b.read(&mut sb[i], |s| (s.xi, s.kalman.mean.clone()));
                assert_eq!(a.0.to_bits(), b.0.to_bits(), "t={t} i={i} xi");
                for d in 0..DZ {
                    assert_eq!(a.1[d].to_bits(), b.1[d].to_bits(), "t={t} i={i} mean[{d}]");
                }
            }
        }
        for s in sa {
            heap_a.release(s);
        }
        for s in sb {
            heap_b.release(s);
        }
    }

    #[test]
    fn simulation_declines_batched_hook() {
        // Pseudo-observation sampling is per-particle RNG work; the hook
        // must send the coordinator to the scalar path.
        let model = Rbpf::synthetic(5, 3);
        let pool = ThreadPool::new(1);
        let mut heap = crate::heap::Heap::new(CopyMode::LazySro);
        let mut states: Vec<_> = (0..4)
            .map(|i| model.init(&mut heap, &mut particle_rng(7, 0, i)))
            .collect();
        assert!(model
            .step_batched(&mut heap, &mut states, 1, 7, false, 0, &ctx(&pool))
            .is_none());
        for s in states {
            heap.release(s);
        }
    }

    #[test]
    fn filter_runs_and_cleans_up_all_modes() {
        let model = Rbpf::synthetic(20, 3);
        let pool = ThreadPool::new(2);
        let mut evidences = Vec::new();
        for mode in CopyMode::ALL {
            let mut heap = crate::heap::Heap::new(mode);
            let r = run_filter(&model, &cfg(64, 20, mode), &mut heap, &ctx(&pool), Method::Bootstrap);
            assert!(r.log_evidence.is_finite());
            assert_eq!(heap.live_objects(), 0, "{mode:?} leaked");
            evidences.push(r.log_evidence);
        }
        assert_eq!(evidences[0].to_bits(), evidences[1].to_bits());
        assert_eq!(evidences[1].to_bits(), evidences[2].to_bits());
    }

    #[test]
    fn simulation_task_no_copies() {
        let model = Rbpf::synthetic(15, 4);
        let pool = ThreadPool::new(1);
        let mut c = cfg(32, 15, CopyMode::LazySro);
        c.task = Task::Simulation;
        let mut heap = crate::heap::Heap::new(CopyMode::LazySro);
        let _ = run_filter(&model, &c, &mut heap, &ctx(&pool), Method::Bootstrap);
        assert_eq!(heap.metrics.deep_copies, 0);
    }
}
