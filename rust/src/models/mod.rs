//! The evaluation models of the paper's §4, plus the Table 1/2 linked-list
//! microbenchmark, and the dispatch layer that maps a [`RunConfig`] cell
//! to a complete run.

pub mod crbd;
pub mod list;
pub mod mot;
pub mod pcfg;
pub mod rbpf;
pub mod vbd;

pub use crbd::Crbd;
pub use list::ListModel;
pub use mot::Mot;
pub use pcfg::Pcfg;
pub use rbpf::Rbpf;
pub use vbd::Vbd;

use crate::config::{Model, RunConfig};
use crate::heap::ShardedHeap;
use crate::smc::{run_filter_shards, run_particle_gibbs_shards, FilterResult, Method, StepCtx};

/// Seed for synthetic data generation — fixed so every run of a given
/// problem sees the same data, independent of the inference seed.
pub const DATA_SEED: u64 = 0xDA7A_5EED;

/// Run the configured (problem, task, mode) cell with the method the
/// paper's §4 pairs with that problem, over the given sharded heap (the
/// shard count is fixed by the caller when constructing the
/// [`ShardedHeap`]; outputs are identical for every shard count).
/// Particle Gibbs (VBD) aggregates its iterations into one result (series
/// concatenated, evidence = last iteration's).
pub fn run_model(cfg: &RunConfig, heap: &mut ShardedHeap, ctx: &StepCtx) -> FilterResult {
    // A nonzero cfg.shards is authoritative: silently running a different
    // K than the config names would make sweep records lie.
    assert!(
        cfg.shards == 0 || cfg.shards == heap.k(),
        "RunConfig.shards = {} but the ShardedHeap has K = {}",
        cfg.shards,
        heap.k()
    );
    let shards = heap.shards_mut();
    match cfg.model {
        Model::Rbpf => {
            let m = Rbpf::synthetic(cfg.n_steps, DATA_SEED);
            run_filter_shards(&m, cfg, shards, ctx, Method::Bootstrap)
        }
        Model::Pcfg => {
            let m = Pcfg::synthetic(cfg.n_steps, DATA_SEED);
            run_filter_shards(&m, cfg, shards, ctx, Method::Auxiliary)
        }
        Model::Vbd => {
            let m = Vbd::synthetic(cfg.n_steps, DATA_SEED);
            if cfg.task == crate::config::Task::Inference {
                let results = run_particle_gibbs_shards(&m, cfg, shards, ctx);
                aggregate_pg(results)
            } else {
                run_filter_shards(&m, cfg, shards, ctx, Method::Bootstrap)
            }
        }
        Model::Mot => {
            let m = Mot::synthetic(cfg.n_steps, DATA_SEED);
            run_filter_shards(&m, cfg, shards, ctx, Method::Bootstrap)
        }
        Model::Crbd => {
            // CRBD's horizon is fixed by the tree: scale tips so that the
            // event count tracks the configured T (paper: 173 events).
            let tips = (cfg.n_steps + 1).max(3);
            let m = Crbd::synthetic(tips, DATA_SEED);
            run_filter_shards(&m, cfg, shards, ctx, Method::Alive)
        }
        Model::List => {
            let m = ListModel::synthetic(cfg.n_steps, DATA_SEED);
            run_filter_shards(&m, cfg, shards, ctx, Method::Bootstrap)
        }
    }
}

fn aggregate_pg(results: Vec<FilterResult>) -> FilterResult {
    let mut iter = results.into_iter();
    let mut acc = iter.next().expect("at least one PG iteration");
    let mut t_off = acc.series.last().map(|s| s.t).unwrap_or(0);
    for r in iter {
        acc.log_evidence = r.log_evidence;
        acc.posterior_mean = r.posterior_mean;
        acc.wall_s += r.wall_s;
        acc.peak_bytes = acc.peak_bytes.max(r.peak_bytes);
        acc.global_peak_bytes = acc.global_peak_bytes.max(r.global_peak_bytes);
        acc.scratch_peak_bytes = acc.scratch_peak_bytes.max(r.scratch_peak_bytes);
        acc.migrations += r.migrations;
        acc.steals += r.steals;
        acc.attempts += r.attempts;
        for mut s in r.series {
            s.t += t_off;
            acc.series.push(s);
        }
        t_off = acc.series.last().map(|s| s.t).unwrap_or(t_off);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Model, RunConfig, Task};
    use crate::heap::CopyMode;
    use crate::pool::ThreadPool;

    /// Every (problem × task × mode) cell runs end-to-end at tiny scale,
    /// cleans up the heap, and produces identical output across modes —
    /// the whole §4 matrix in miniature.
    #[test]
    fn full_experiment_matrix_smoke() {
        let pool = ThreadPool::new(2);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        for model in Model::EVAL {
            for task in [Task::Inference, Task::Simulation] {
                let mut outs = Vec::new();
                for mode in CopyMode::ALL {
                    let mut cfg = RunConfig::for_model(model, task, mode);
                    cfg.n_particles = 24;
                    cfg.n_steps = 12;
                    cfg.pg_iterations = 2;
                    cfg.seed = 99;
                    let mut heap = ShardedHeap::new(mode, 1);
                    let r = run_model(&cfg, &mut heap, &ctx);
                    assert_eq!(
                        heap.live_objects(),
                        0,
                        "{model:?}/{task:?}/{mode:?} leaked"
                    );
                    outs.push((r.log_evidence, r.posterior_mean));
                }
                if task == Task::Inference {
                    assert_eq!(
                        outs[0].0.to_bits(),
                        outs[1].0.to_bits(),
                        "{model:?}: eager vs lazy evidence"
                    );
                    assert_eq!(
                        outs[1].0.to_bits(),
                        outs[2].0.to_bits(),
                        "{model:?}: lazy vs lazy-sro evidence"
                    );
                }
            }
        }
    }

    /// Shard-count invariance across the full model matrix: every
    /// problem's dispatch path (bootstrap, auxiliary, alive, particle
    /// Gibbs) must produce bit-identical inference output with K = 3
    /// shards as with K = 1, with all shards cleaned up and the
    /// alloc/free balance intact.
    #[test]
    fn full_experiment_matrix_shard_invariant() {
        let pool = ThreadPool::new(3);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        for model in Model::EVAL {
            let mut outs = Vec::new();
            for k in [1usize, 3] {
                let mut cfg = RunConfig::for_model(model, Task::Inference, CopyMode::LazySro);
                cfg.n_particles = 24;
                cfg.n_steps = 12;
                cfg.pg_iterations = 2;
                cfg.seed = 99;
                let mut heap = ShardedHeap::new(CopyMode::LazySro, k);
                let r = run_model(&cfg, &mut heap, &ctx);
                assert_eq!(heap.live_objects(), 0, "{model:?} K={k} leaked");
                let m = heap.metrics();
                assert_eq!(
                    m.total_allocs,
                    m.total_frees + m.live_objects,
                    "{model:?} K={k}: alloc/free balance"
                );
                outs.push((r.log_evidence, r.posterior_mean));
            }
            assert_eq!(
                outs[0].0.to_bits(),
                outs[1].0.to_bits(),
                "{model:?}: K=1 vs K=3 evidence"
            );
            assert_eq!(
                outs[0].1.to_bits(),
                outs[1].1.to_bits(),
                "{model:?}: K=1 vs K=3 posterior mean"
            );
        }
    }
}
