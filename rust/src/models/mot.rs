//! MOT: multi-object tracking with an unknown number of objects and
//! linear-Gaussian per-track dynamics (Murray & Schön 2018).
//!
//! Each particle holds a **ragged array** of track objects — separate heap
//! allocations referenced from the particle state, so per-object
//! granularity sharing applies (the platform's point versus page-level
//! COW, §1). Track beliefs are *append-only*: a track node stores the
//! Kalman belief at its last association time plus a back-pointer to its
//! previous node; unassociated tracks are untouched (shared across the
//! whole population and across generations), and catch-up prediction for
//! association is recomputed deterministically from the node's timestamp.
//! Only associated tracks allocate a new node per generation.
//!
//! Paper scale: N = 4096, T = 100 (inference) / 300 (simulation).
//! Data: simulated (as in the paper).

use crate::heap::{Heap, Lazy};
use crate::lazy_fields;
use crate::linalg::Mat;
use crate::ppl::KalmanState;
use crate::rng::Pcg64;
use crate::smc::SmcModel;

const P_DEATH: f64 = 0.03;
const BIRTH_RATE: f64 = 0.25;
const CLUTTER_RATE: f64 = 1.0;
const P_DETECT: f64 = 0.9;
const ARENA: f64 = 20.0;
const OBS_VAR: f64 = 0.25;
const Q_POS: f64 = 0.01;
const Q_VEL: f64 = 0.05;
/// Association gate (squared distance).
const GATE: f64 = 9.0;

/// One tracked object's belief and history.
#[derive(Clone)]
pub struct Track {
    /// Belief at generation `updated_t` (position/velocity, 4-D CV model).
    pub kalman: KalmanState,
    /// Generation of the last measurement update.
    pub updated_t: u32,
    /// Previous snapshot of this track (its history chain).
    pub prev: Lazy<Track>,
}
lazy_fields!(Track: prev);

/// A particle's hypothesis: the current set of tracks.
#[derive(Clone, Default)]
pub struct MotState {
    /// Live tracks (a ragged array of lazy pointers).
    pub tracks: Vec<Lazy<Track>>,
    /// Previous generation's hypothesis (the history chain).
    pub prev: Lazy<MotState>,
}
lazy_fields!(MotState: tracks, prev);

/// The multi-object tracking model (births, deaths, clutter, gating).
///
/// `Clone` supports what-if serving: speculative branches clone the
/// model and append hypothetical scans without disturbing the live
/// observation stream.
#[derive(Clone)]
pub struct Mot {
    /// Observed 2-D points per generation.
    pub obs: Vec<Vec<(f64, f64)>>,
}

fn cv_a() -> Mat {
    Mat::from_rows(&[
        &[1.0, 0.0, 1.0, 0.0],
        &[0.0, 1.0, 0.0, 1.0],
        &[0.0, 0.0, 1.0, 0.0],
        &[0.0, 0.0, 0.0, 1.0],
    ])
}

fn cv_q() -> Mat {
    Mat::from_rows(&[
        &[Q_POS, 0.0, 0.0, 0.0],
        &[0.0, Q_POS, 0.0, 0.0],
        &[0.0, 0.0, Q_VEL, 0.0],
        &[0.0, 0.0, 0.0, Q_VEL],
    ])
}

fn obs_c() -> Mat {
    Mat::from_rows(&[&[1.0, 0.0, 0.0, 0.0], &[0.0, 1.0, 0.0, 0.0]])
}

fn obs_r() -> Mat {
    Mat::from_rows(&[&[OBS_VAR, 0.0], &[0.0, OBS_VAR]])
}

fn new_track_belief(px: f64, py: f64) -> KalmanState {
    let mut cov = Mat::eye(4);
    *cov.at_mut(2, 2) = 0.5;
    *cov.at_mut(3, 3) = 0.5;
    KalmanState::new(vec![px, py, 0.0, 0.0], cov)
}

/// Deterministic catch-up prediction: advance a belief `k` generations.
fn predict_k(mut ks: KalmanState, k: u32) -> KalmanState {
    let a = cv_a();
    let q = cv_q();
    for _ in 0..k {
        ks.predict(&a, &[0.0; 4], &q);
    }
    ks
}

/// log-pmf of the clutter configuration.
fn clutter_ll(k: usize) -> f64 {
    crate::rng::poisson_lpmf(k as u64, CLUTTER_RATE) - (k as f64) * (ARENA * ARENA).ln()
}

impl Mot {
    /// A model with **no scans yet** — the incremental-ingest starting
    /// point for the `serve` subcommand (scans arrive via
    /// [`stream_observation`](SmcModel::stream_observation)).
    pub fn streaming() -> Self {
        Mot { obs: Vec::new() }
    }

    /// Simulate ground-truth tracks + clutter into an observation set.
    pub fn synthetic(t_max: usize, seed: u64) -> Self {
        let mut rng = Pcg64::stream(seed, 0x0707);
        let mut truth: Vec<(f64, f64, f64, f64)> = Vec::new();
        let mut obs = Vec::with_capacity(t_max);
        for _ in 0..t_max {
            for _ in 0..rng.poisson(BIRTH_RATE) {
                truth.push((
                    rng.uniform(-ARENA / 2.0, ARENA / 2.0),
                    rng.uniform(-ARENA / 2.0, ARENA / 2.0),
                    rng.gaussian(0.0, 0.3),
                    rng.gaussian(0.0, 0.3),
                ));
            }
            truth.retain(|_| rng.next_f64() > P_DEATH);
            let mut pts = Vec::new();
            for tr in truth.iter_mut() {
                tr.0 += tr.2 + rng.gaussian(0.0, Q_POS.sqrt());
                tr.1 += tr.3 + rng.gaussian(0.0, Q_POS.sqrt());
                tr.2 += rng.gaussian(0.0, Q_VEL.sqrt());
                tr.3 += rng.gaussian(0.0, Q_VEL.sqrt());
                if rng.next_f64() < P_DETECT {
                    pts.push((
                        tr.0 + rng.gaussian(0.0, OBS_VAR.sqrt()),
                        tr.1 + rng.gaussian(0.0, OBS_VAR.sqrt()),
                    ));
                }
            }
            for _ in 0..rng.poisson(CLUTTER_RATE) {
                pts.push((
                    rng.uniform(-ARENA / 2.0, ARENA / 2.0),
                    rng.uniform(-ARENA / 2.0, ARENA / 2.0),
                ));
            }
            obs.push(pts);
        }
        Mot { obs }
    }
}

impl SmcModel for Mot {
    type State = MotState;

    fn name(&self) -> &'static str {
        "mot"
    }

    fn horizon(&self) -> usize {
        self.obs.len()
    }

    fn init(&self, heap: &mut Heap, _rng: &mut Pcg64) -> Lazy<MotState> {
        heap.alloc(MotState::default())
    }

    fn step(
        &self,
        heap: &mut Heap,
        state: &mut Lazy<MotState>,
        t: usize,
        rng: &mut Pcg64,
        observe: bool,
    ) -> f64 {
        // Borrow the previous generation's track pointers (shared).
        let n_prev = heap.read(state, |s| s.tracks.len());
        let mut tracks: Vec<Lazy<Track>> = (0..n_prev)
            .map(|i| heap.read_ptr(state, |s| s.tracks[i]))
            .collect();
        // Deaths.
        tracks.retain(|_| rng.next_f64() > P_DEATH);
        // Stack handles created this step (births + association updates),
        // released once the new state node owns its stored edges.
        let mut owned: Vec<Lazy<Track>> = Vec::new();
        // Births (fresh nodes, no history).
        for _ in 0..rng.poisson(BIRTH_RATE) {
            let px = rng.uniform(-ARENA / 2.0, ARENA / 2.0);
            let py = rng.uniform(-ARENA / 2.0, ARENA / 2.0);
            let tr = heap.alloc(Track {
                kalman: new_track_belief(px, py),
                updated_t: t as u32,
                prev: Lazy::NULL,
            });
            tracks.push(tr);
            owned.push(tr);
        }

        let mut ll = 0.0;
        if observe {
            let points = &self.obs[t - 1];
            let mut used = vec![false; points.len()];
            let c = obs_c();
            let r = obs_r();
            for track in tracks.iter_mut() {
                // Read-only catch-up prediction for gating.
                let (belief, updated_t) =
                    heap.read(track, |tr| (tr.kalman.clone(), tr.updated_t));
                let stale = (t as u32).saturating_sub(updated_t);
                let predicted = predict_k(belief, stale);
                let (px, py) = (predicted.mean[0], predicted.mean[1]);
                let mut best: Option<(usize, f64)> = None;
                for (j, p) in points.iter().enumerate() {
                    if used[j] {
                        continue;
                    }
                    let d2 = (p.0 - px).powi(2) + (p.1 - py).powi(2);
                    if best.map(|(_, b)| d2 < b).unwrap_or(true) {
                        best = Some((j, d2));
                    }
                }
                match best {
                    Some((j, d2)) if d2 < GATE => {
                        // Associated: update and append a new snapshot;
                        // the old node stays shared with other particles.
                        used[j] = true;
                        let mut updated = predicted;
                        let y = [points[j].0, points[j].1];
                        ll += P_DETECT.ln();
                        ll += updated.update(&c, &r, &y);
                        let old = *track;
                        let new = heap.alloc(Track {
                            kalman: updated,
                            updated_t: t as u32,
                            prev: old,
                        });
                        *track = new;
                        owned.push(new);
                    }
                    _ => ll += (1.0 - P_DETECT).ln(),
                }
            }
            let n_clutter = used.iter().filter(|u| !**u).count();
            ll += clutter_ll(n_clutter);
        }

        // New generation node referencing the (partly refreshed) tracks.
        let old = *state;
        let new = heap.alloc(MotState {
            tracks: tracks.clone(),
            prev: old,
        });
        heap.release(old);
        *state = new;
        // Stored edges own their counts; drop this step's stack handles.
        // (Borrowed pointers to shared old tracks are not released.)
        for h in owned {
            heap.release(h);
        }
        if observe {
            ll
        } else {
            0.0
        }
    }

    /// Propagation cost tracks the ragged track-array length: every live
    /// track is predicted and gated against each observation, so a
    /// particle with many tracks dominates its shard's generation time.
    fn cost_hint(&self, heap: &mut Heap, state: &mut Lazy<MotState>) -> f64 {
        heap.read(state, |s| s.tracks.len() as f64 + 1.0)
    }

    fn summary(&self, heap: &mut Heap, state: &mut Lazy<MotState>) -> f64 {
        heap.read(state, |s| s.tracks.len() as f64)
    }

    /// One scan per generation: zero or more detections, each a comma
    /// -joined `x,y` pair. No tokens at all is a legitimate empty scan
    /// (the sensor saw nothing this generation).
    fn stream_observation(&mut self, tokens: &[&str]) -> Result<(), String> {
        let mut pts = Vec::with_capacity(tokens.len());
        for tok in tokens {
            let Some((sx, sy)) = tok.split_once(',') else {
                return Err(format!("mot detection '{tok}' is not an x,y pair"));
            };
            let x: f64 = sx
                .parse()
                .map_err(|_| format!("mot detection x '{sx}' is not a number"))?;
            let y: f64 = sy
                .parse()
                .map_err(|_| format!("mot detection y '{sy}' is not a number"))?;
            if !x.is_finite() || !y.is_finite() {
                return Err(format!("mot detection '{tok}' must be finite"));
            }
            pts.push((x, y));
        }
        self.obs.push(pts);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Model, RunConfig, Task};
    use crate::heap::{CopyMode, Heap};
    use crate::pool::ThreadPool;
    use crate::smc::{run_filter, Method, StepCtx};

    #[test]
    fn synthetic_observations_reproducible() {
        let a = Mot::synthetic(30, 1);
        let b = Mot::synthetic(30, 1);
        assert_eq!(a.obs.len(), 30);
        for (x, y) in a.obs.iter().zip(&b.obs) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn predict_k_matches_iterated_predict() {
        let ks = new_track_belief(1.0, -2.0);
        let once = predict_k(ks.clone(), 3);
        let mut manual = ks;
        let (a, q) = (cv_a(), cv_q());
        for _ in 0..3 {
            manual.predict(&a, &[0.0; 4], &q);
        }
        assert_eq!(once, manual);
    }

    #[test]
    fn filter_tracks_share_and_cleanup() {
        let model = Mot::synthetic(15, 2);
        let pool = ThreadPool::new(1);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        let mut out = Vec::new();
        for mode in CopyMode::ALL {
            let mut c = RunConfig::for_model(Model::Mot, Task::Inference, mode);
            c.n_particles = 32;
            c.n_steps = 15;
            c.seed = 9;
            let mut heap = Heap::new(mode);
            let r = run_filter(&model, &c, &mut heap, &ctx, Method::Bootstrap);
            assert!(r.log_evidence.is_finite());
            out.push((r.log_evidence, r.posterior_mean));
            assert_eq!(heap.live_objects(), 0, "{mode:?} leaked");
        }
        assert_eq!(out[0].0.to_bits(), out[1].0.to_bits());
        assert_eq!(out[1].0.to_bits(), out[2].0.to_bits());
    }

    #[test]
    fn lazy_shares_untouched_tracks() {
        // Append-only track nodes: lazy peak memory must undercut eager.
        let model = Mot::synthetic(40, 3);
        let pool = ThreadPool::new(1);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        let mut peaks = Vec::new();
        for mode in [CopyMode::Eager, CopyMode::LazySro] {
            let mut c = RunConfig::for_model(Model::Mot, Task::Inference, mode);
            c.n_particles = 64;
            c.n_steps = 40;
            c.seed = 4;
            let mut heap = Heap::new(mode);
            let r = run_filter(&model, &c, &mut heap, &ctx, Method::Bootstrap);
            peaks.push(r.peak_bytes as f64);
        }
        assert!(
            peaks[1] < peaks[0] * 0.6,
            "lazy peak {} not well below eager peak {}",
            peaks[1],
            peaks[0]
        );
    }

    #[test]
    fn simulation_no_copies() {
        let model = Mot::synthetic(20, 5);
        let pool = ThreadPool::new(1);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        let mut c = RunConfig::for_model(Model::Mot, Task::Simulation, CopyMode::LazySro);
        c.n_particles = 16;
        c.n_steps = 20;
        let mut heap = Heap::new(CopyMode::LazySro);
        let _ = run_filter(&model, &c, &mut heap, &ctx, Method::Bootstrap);
        assert_eq!(heap.metrics.deep_copies, 0);
    }
}
