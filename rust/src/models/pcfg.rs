//! PCFG: probabilistic context-free grammar with an auxiliary particle
//! filter and custom proposal (Pitt & Shephard 1999).
//!
//! Each particle carries a derivation **stack** of grammar symbols — a
//! data structure of random, unbounded size (the paper's motivating §1
//! list) — and *only the latest state* is kept (no history chain), so lazy
//! copies are expected to yield a constant-factor improvement at most (the
//! paper's own PCFG caveat in §4).
//!
//! Generative process per generation: pop symbols, expanding nonterminals
//! by sampled rules, until a preterminal pops; it emits a terminal, which
//! is conditioned on the observed symbol (weight = emission likelihood).
//! The APF lookahead is the exact one-step-ahead probability of the next
//! observed terminal given the stack top (precomputed first-terminal
//! distributions). Rule probabilities use Dirichlet-style pseudocounts via
//! beta–binomial style accumulators kept fixed here (known grammar).
//!
//! Paper scale: N = 16384, T = 3262 (inference) / 2000 (simulation).
//! Data: unpublished model in the paper → a standard toy grammar here,
//! corpus sampled from the grammar itself.

use crate::heap::{Heap, Lazy};
use crate::lazy_fields;
use crate::rng::Pcg64;
use crate::smc::SmcModel;

/// Number of terminal symbols the grammar emits.
pub const N_TERMINALS: usize = 3;

/// Symbols: 0..N_NT are nonterminals, N_NT..N_NT+N_PT preterminals.
const S: u8 = 0;
const A: u8 = 1;
const B: u8 = 2;
const PX: u8 = 3;
const PY: u8 = 4;
const N_SYMBOLS: usize = 5;

/// A production rule: probability + right-hand side (pushed reversed).
struct Rule {
    p: f64,
    rhs: &'static [u8],
}

fn rules(nt: u8) -> &'static [Rule] {
    match nt {
        S => &[
            Rule { p: 0.4, rhs: &[PX, A] },
            Rule { p: 0.4, rhs: &[PY, B] },
            Rule { p: 0.2, rhs: &[PX] },
        ],
        A => &[
            Rule { p: 0.6, rhs: &[PY] },
            Rule { p: 0.25, rhs: &[PX, A] },
            Rule { p: 0.15, rhs: &[PY, S] },
        ],
        B => &[
            Rule { p: 0.5, rhs: &[PX] },
            Rule { p: 0.3, rhs: &[PY, B] },
            Rule { p: 0.2, rhs: &[PX, S] },
        ],
        _ => unreachable!("not a nonterminal"),
    }
}

/// Emission distributions for preterminals over terminals {x, y, z}.
fn emissions(pt: u8) -> &'static [f64; N_TERMINALS] {
    match pt {
        PX => &[0.7, 0.0, 0.3],
        PY => &[0.0, 0.8, 0.2],
        _ => unreachable!("not a preterminal"),
    }
}

/// A particle's derivation state.
#[derive(Clone, Default)]
pub struct PcfgState {
    /// Derivation stack, top at the end. Grows and shrinks in place —
    /// exactly the mutation pattern whose copies the platform defers.
    pub stack: Vec<u8>,
    /// Terminals emitted so far.
    pub emitted: u64,
    /// Dummy pointer field so the payload exercises the edge machinery
    /// even though PCFG states don't chain.
    pub prev: Lazy<PcfgState>,
}
lazy_fields!(PcfgState: prev);

/// The PCFG model: infer the derivation of an observed terminal string.
///
/// `Clone` supports what-if serving: speculative branches clone the
/// model and append hypothetical terminals without disturbing the live
/// corpus.
#[derive(Clone)]
pub struct Pcfg {
    /// Observed terminal string.
    pub obs: Vec<u8>,
    /// first_term[sym][terminal]: probability that the next emitted
    /// terminal is `terminal` given `sym` is on top (exact fixed point).
    first_term: Vec<[f64; N_TERMINALS]>,
}

impl Pcfg {
    /// A model over the given terminal string.
    pub fn new(obs: Vec<u8>) -> Self {
        // Fixed-point computation of first-terminal distributions.
        let mut first = vec![[0.0; N_TERMINALS]; N_SYMBOLS];
        for pt in [PX, PY] {
            first[pt as usize] = *emissions(pt);
        }
        for _ in 0..64 {
            for nt in [S, A, B] {
                let mut acc = [0.0; N_TERMINALS];
                for r in rules(nt) {
                    let head = r.rhs[0] as usize;
                    for k in 0..N_TERMINALS {
                        acc[k] += r.p * first[head][k];
                    }
                }
                first[nt as usize] = acc;
            }
        }
        Pcfg {
            obs,
            first_term: first,
        }
    }

    /// A model with the known grammar and **no corpus yet** — the
    /// incremental-ingest starting point for the `serve` subcommand
    /// (terminals arrive via
    /// [`stream_observation`](SmcModel::stream_observation)).
    pub fn streaming() -> Self {
        Pcfg::new(Vec::new())
    }

    /// Sample a corpus of `t_max` terminals from the grammar.
    pub fn synthetic(t_max: usize, seed: u64) -> Self {
        let mut rng = Pcg64::stream(seed, 0x9CF6);
        let mut stack = vec![S];
        let mut obs = Vec::with_capacity(t_max);
        while obs.len() < t_max {
            match stack.pop() {
                None => stack.push(S),
                Some(sym) if sym >= PX => {
                    let e = emissions(sym);
                    obs.push(rng.categorical(e) as u8);
                }
                Some(nt) => {
                    let rs = rules(nt);
                    let probs: Vec<f64> = rs.iter().map(|r| r.p).collect();
                    let k = rng.categorical(&probs);
                    for &s in rs[k].rhs.iter().rev() {
                        stack.push(s);
                    }
                }
            }
        }
        Pcfg::new(obs)
    }
}

impl SmcModel for Pcfg {
    type State = PcfgState;

    fn name(&self) -> &'static str {
        "pcfg"
    }

    fn horizon(&self) -> usize {
        self.obs.len()
    }

    fn init(&self, heap: &mut Heap, _rng: &mut Pcg64) -> Lazy<PcfgState> {
        heap.alloc(PcfgState {
            stack: vec![S],
            emitted: 0,
            prev: Lazy::NULL,
        })
    }

    fn step(
        &self,
        heap: &mut Heap,
        state: &mut Lazy<PcfgState>,
        t: usize,
        rng: &mut Pcg64,
        observe: bool,
    ) -> f64 {
        // Make the state writable once (copy-on-write happens here), then
        // run the expansion loop in place.
        let y = if observe { Some(self.obs[t - 1]) } else { None };
        let mut ll = 0.0;
        heap.mutate_root(state, |s| {
            loop {
                let top = match s.stack.pop() {
                    None => {
                        s.stack.push(S);
                        continue;
                    }
                    Some(sym) => sym,
                };
                if top >= PX {
                    // Preterminal: emit, conditioning on the observation.
                    let e = emissions(top);
                    match y {
                        Some(obs_sym) => {
                            let p = e[obs_sym as usize];
                            ll = if p > 0.0 { p.ln() } else { f64::NEG_INFINITY };
                        }
                        None => {
                            let _ = rng.categorical(e);
                        }
                    }
                    s.emitted += 1;
                    break;
                }
                // Nonterminal: expand by a sampled rule.
                let rs = rules(top);
                let probs: Vec<f64> = rs.iter().map(|r| r.p).collect();
                let k = rng.categorical(&probs);
                for &sym in rs[k].rhs.iter().rev() {
                    s.stack.push(sym);
                }
                // Safety valve against pathological stack growth.
                if s.stack.len() > 10_000 {
                    s.stack.truncate(1);
                }
            }
        });
        ll
    }

    /// Exact one-step lookahead: P(y_t | stack top) — the APF's custom
    /// proposal score.
    fn lookahead(&self, heap: &mut Heap, state: &mut Lazy<PcfgState>, t: usize) -> Option<f64> {
        let y = self.obs[t - 1] as usize;
        let top = heap.read(state, |s| s.stack.last().copied());
        let sym = top.unwrap_or(S) as usize;
        let p = self.first_term[sym][y];
        Some(if p > 0.0 { p.ln() } else { -30.0 })
    }

    /// Propagation cost tracks the derivation-stack depth: a deep stack
    /// keeps expanding nonterminals (and copying on write) long after a
    /// shallow one has emitted — the heavy-tailed per-particle cost the
    /// shard rebalancer exists to even out.
    fn cost_hint(&self, heap: &mut Heap, state: &mut Lazy<PcfgState>) -> f64 {
        heap.read(state, |s| s.stack.len() as f64 + 1.0)
    }

    fn summary(&self, heap: &mut Heap, state: &mut Lazy<PcfgState>) -> f64 {
        heap.read(state, |s| s.stack.len() as f64)
    }

    /// One observation per generation: a terminal-symbol id in
    /// `0..N_TERMINALS`.
    fn stream_observation(&mut self, tokens: &[&str]) -> Result<(), String> {
        let [tok] = tokens else {
            return Err(format!(
                "pcfg expects exactly one terminal id per generation, got {} tokens",
                tokens.len()
            ));
        };
        let y: usize = tok
            .parse()
            .map_err(|_| format!("pcfg terminal '{tok}' is not an integer"))?;
        if y >= N_TERMINALS {
            return Err(format!(
                "pcfg terminal {y} out of range (alphabet is 0..{N_TERMINALS})"
            ));
        }
        self.obs.push(y as u8);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Model, RunConfig, Task};
    use crate::heap::{CopyMode, Heap};
    use crate::pool::ThreadPool;
    use crate::smc::{run_filter, Method, StepCtx};

    #[test]
    fn first_terminal_distributions_normalize() {
        let m = Pcfg::synthetic(10, 1);
        for sym in 0..N_SYMBOLS {
            let s: f64 = m.first_term[sym].iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "sym {sym}: {s}");
        }
    }

    #[test]
    fn corpus_reproducible_and_in_alphabet() {
        let a = Pcfg::synthetic(200, 5);
        let b = Pcfg::synthetic(200, 5);
        assert_eq!(a.obs, b.obs);
        assert!(a.obs.iter().all(|&s| (s as usize) < N_TERMINALS));
    }

    #[test]
    fn apf_beats_or_matches_bootstrap_on_evidence_variance() {
        let model = Pcfg::synthetic(30, 2);
        let pool = ThreadPool::new(1);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        let run = |method, seed| {
            let mut c = RunConfig::for_model(Model::Pcfg, Task::Inference, CopyMode::LazySro);
            c.n_particles = 256;
            c.n_steps = 30;
            c.seed = seed;
            let mut heap = Heap::new(CopyMode::LazySro);
            let r = run_filter(&model, &c, &mut heap, &ctx, method);
            assert_eq!(heap.live_objects(), 0);
            r.log_evidence
        };
        let boot: Vec<f64> = (0..5).map(|s| run(Method::Bootstrap, s)).collect();
        let apf: Vec<f64> = (0..5).map(|s| run(Method::Auxiliary, s)).collect();
        // Both must be finite and in the same ballpark.
        for v in boot.iter().chain(&apf) {
            assert!(v.is_finite(), "evidence estimates: {boot:?} {apf:?}");
        }
        let mb = crate::stats::mean(&boot);
        let ma = crate::stats::mean(&apf);
        assert!((mb - ma).abs() < 10.0, "bootstrap {mb} vs apf {ma}");
    }

    #[test]
    fn modes_agree_bitwise() {
        let model = Pcfg::synthetic(25, 3);
        let pool = ThreadPool::new(1);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        let mut out = Vec::new();
        for mode in CopyMode::ALL {
            let mut c = RunConfig::for_model(Model::Pcfg, Task::Inference, mode);
            c.n_particles = 64;
            c.n_steps = 25;
            c.seed = 11;
            let mut heap = Heap::new(mode);
            let r = run_filter(&model, &c, &mut heap, &ctx, Method::Auxiliary);
            out.push(r.log_evidence);
        }
        assert_eq!(out[0].to_bits(), out[1].to_bits());
        assert_eq!(out[1].to_bits(), out[2].to_bits());
    }

    #[test]
    fn simulation_emits_without_conditioning() {
        let model = Pcfg::synthetic(40, 4);
        let pool = ThreadPool::new(1);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        let mut c = RunConfig::for_model(Model::Pcfg, Task::Simulation, CopyMode::Lazy);
        c.n_particles = 16;
        c.n_steps = 40;
        let mut heap = Heap::new(CopyMode::Lazy);
        let r = run_filter(&model, &c, &mut heap, &ctx, Method::Bootstrap);
        assert!(r.log_evidence.is_nan());
        assert_eq!(heap.metrics.deep_copies, 0);
    }
}
