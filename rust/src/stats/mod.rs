//! Numerical statistics utilities: log-sum-exp weight handling, effective
//! sample size, weighted moments, and quantiles (median + IQR, the
//! statistics reported in the paper's Figures 5–6).

/// log(Σ exp(x_i)) computed stably.
pub fn log_sum_exp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if m == f64::NEG_INFINITY {
        return f64::NEG_INFINITY;
    }
    let s: f64 = xs.iter().map(|x| (x - m).exp()).sum();
    m + s.ln()
}

/// Normalize log weights in place to plain weights summing to 1; returns
/// the log of the mean weight (the incremental evidence contribution).
pub fn normalize_log_weights(lw: &[f64], out: &mut Vec<f64>) -> f64 {
    let lse = log_sum_exp(lw);
    out.clear();
    if lse == f64::NEG_INFINITY {
        out.resize(lw.len(), 1.0 / lw.len() as f64);
        return f64::NEG_INFINITY;
    }
    out.extend(lw.iter().map(|x| (x - lse).exp()));
    lse - (lw.len() as f64).ln()
}

/// Single-pass fusion of [`normalize_log_weights`] and [`ess`]: returns
/// `(log mean weight, effective sample size)` and fills `out` with the
/// normalized weights. The squared-weight accumulator runs in the same
/// left-to-right order as a separate [`ess`] pass over `out`, so the
/// result is bit-identical to the two-pass sequence while touching the
/// population once instead of twice per generation.
pub fn weight_stats(lw: &[f64], out: &mut Vec<f64>) -> (f64, f64) {
    let lse = log_sum_exp(lw);
    out.clear();
    if lse == f64::NEG_INFINITY {
        out.resize(lw.len(), 1.0 / lw.len() as f64);
        let s: f64 = out.iter().map(|x| x * x).sum();
        return (f64::NEG_INFINITY, if s > 0.0 { 1.0 / s } else { 0.0 });
    }
    let mut s = 0.0;
    out.extend(lw.iter().map(|x| {
        let w = (x - lse).exp();
        s += w * w;
        w
    }));
    let e = if s > 0.0 { 1.0 / s } else { 0.0 };
    (lse - (lw.len() as f64).ln(), e)
}

/// Effective sample size of normalized weights: 1 / Σ w².
pub fn ess(w: &[f64]) -> f64 {
    let s: f64 = w.iter().map(|x| x * x).sum();
    if s > 0.0 {
        1.0 / s
    } else {
        0.0
    }
}

/// ESS directly from log weights.
pub fn ess_log(lw: &[f64]) -> f64 {
    let l1 = log_sum_exp(lw);
    let l2 = log_sum_exp(&lw.iter().map(|x| 2.0 * x).collect::<Vec<_>>());
    if l1 == f64::NEG_INFINITY {
        0.0
    } else {
        (2.0 * l1 - l2).exp()
    }
}

/// Weighted mean.
pub fn weighted_mean(x: &[f64], w: &[f64]) -> f64 {
    let sw: f64 = w.iter().sum();
    x.iter().zip(w).map(|(a, b)| a * b).sum::<f64>() / sw
}

/// Weighted variance (biased, population form).
pub fn weighted_var(x: &[f64], w: &[f64]) -> f64 {
    let m = weighted_mean(x, w);
    let sw: f64 = w.iter().sum();
    x.iter().zip(w).map(|(a, b)| b * (a - m) * (a - m)).sum::<f64>() / sw
}

/// Quantile (linear interpolation) of an unsorted slice. `q` in [0,1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median and interquartile range — the summary the paper plots.
pub fn median_iqr(xs: &[f64]) -> (f64, f64, f64) {
    (quantile(xs, 0.5), quantile(xs, 0.25), quantile(xs, 0.75))
}

/// Simple mean.
pub fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn sd(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() as f64 - 1.0)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_sum_exp_stable() {
        assert!((log_sum_exp(&[0.0, 0.0]) - 2f64.ln()).abs() < 1e-12);
        // Huge offsets don't overflow.
        let x = log_sum_exp(&[1000.0, 1000.0]);
        assert!((x - (1000.0 + 2f64.ln())).abs() < 1e-9);
        assert_eq!(log_sum_exp(&[f64::NEG_INFINITY; 3]), f64::NEG_INFINITY);
    }

    #[test]
    fn normalize_and_ess() {
        let lw = [0.0, 0.0, 0.0, 0.0];
        let mut w = Vec::new();
        let lmean = normalize_log_weights(&lw, &mut w);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((lmean - 0.0).abs() < 1e-12);
        assert!((ess(&w) - 4.0).abs() < 1e-9);
        // Degenerate weights: ESS 1.
        let lw = [0.0, -1e9, -1e9];
        let _ = normalize_log_weights(&lw, &mut w);
        assert!((ess(&w) - 1.0).abs() < 1e-6);
        assert!((ess_log(&lw) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn weight_stats_matches_two_pass_bitwise() {
        let cases: Vec<Vec<f64>> = vec![
            vec![0.0],
            vec![0.3, -1.7, 2.2, -0.4],
            vec![-700.0, -701.5, -699.2, -700.1, -702.9],
            vec![f64::NEG_INFINITY, -1.0, -2.0],
            vec![f64::NEG_INFINITY; 4],
            (0..257).map(|i| (i as f64) * 0.013 - 1.0).collect(),
        ];
        for lw in &cases {
            let mut w_ref = Vec::new();
            let lmean_ref = normalize_log_weights(lw, &mut w_ref);
            let ess_ref = ess(&w_ref);
            let mut w = Vec::new();
            let (lmean, e) = weight_stats(lw, &mut w);
            assert_eq!(lmean.to_bits(), lmean_ref.to_bits(), "lmean for {lw:?}");
            assert_eq!(e.to_bits(), ess_ref.to_bits(), "ess for {lw:?}");
            assert_eq!(w.len(), w_ref.len());
            for (a, b) in w.iter().zip(&w_ref) {
                assert_eq!(a.to_bits(), b.to_bits(), "weights for {lw:?}");
            }
        }
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        let (med, q1, q3) = median_iqr(&xs);
        assert_eq!(med, 3.0);
        assert_eq!(q1, 2.0);
        assert_eq!(q3, 4.0);
    }

    #[test]
    fn weighted_moments() {
        let x = [1.0, 3.0];
        let w = [1.0, 1.0];
        assert!((weighted_mean(&x, &w) - 2.0).abs() < 1e-12);
        assert!((weighted_var(&x, &w) - 1.0).abs() < 1e-12);
        let w = [3.0, 1.0];
        assert!((weighted_mean(&x, &w) - 1.5).abs() < 1e-12);
    }
}
