//! Batched Kalman measurement/time update: the RBPF numeric hot spot.
//!
//! The L1 Pallas kernel (`python/compile/kernels/kalman.py`) performs, for
//! a batch of particles, the 3-dimensional linear-substate update
//!
//!   m ← A m;  P ← A P Aᵀ + Q;                    (predict)
//!   S = C P Cᵀ + R;  K = P Cᵀ / S;               (gain, scalar obs)
//!   m ← m + K (y − C m);  P ← P − K S Kᵀ;        (update)
//!   ll = log N(y; C m⁻, S)                        (weight)
//!
//! with the model matrices baked in at lowering time. [`BatchKalman`] runs
//! the compiled artifact in padded chunks of [`BATCH`]; [`batch_kalman_cpu`]
//! is the f64 oracle built on [`crate::ppl::KalmanState`], used as the
//! fallback path and in differential tests.

use super::{Artifact, Result, XlaRuntime, BATCH};
use crate::linalg::Mat;
use crate::ppl::KalmanState;

/// Dimension of the linear substate (fixed by the artifact).
pub const DZ: usize = 3;

/// The linear-Gaussian parameters of the RBPF substate. The same constants
/// are baked into the Python-lowered artifact; keep in sync with
/// `python/compile/kernels/kalman.py`.
#[derive(Clone, Debug)]
pub struct KalmanParams {
    /// Dynamics matrix A.
    pub a: Mat,
    /// Process-noise covariance Q.
    pub q: Mat,
    /// Observation row C (scalar observation).
    pub c: Mat,
    /// Observation-noise variance R.
    pub r: f64,
}

impl KalmanParams {
    /// The mixed linear/nonlinear SSM of Lindsten & Schön (2010) — a
    /// rotation-ish stable A, small process noise, scalar observation.
    pub fn rbpf_default() -> Self {
        KalmanParams {
            a: Mat::from_rows(&[&[0.8, 0.1, 0.0], &[-0.1, 0.8, 0.1], &[0.0, -0.1, 0.8]]),
            q: Mat::from_rows(&[&[0.1, 0.0, 0.0], &[0.0, 0.1, 0.0], &[0.0, 0.0, 0.1]]),
            c: Mat::from_rows(&[&[1.0, 0.5, 0.25]]),
            r: 0.5,
        }
    }
}

/// Predict + update + weight for a batch of particles on the CPU oracle
/// path (f64, exact). `means`: N×DZ flattened; `covs`: N×DZ×DZ flattened
/// row-major; `y`: the common observation. Returns per-particle log-liks.
pub fn batch_kalman_cpu(
    params: &KalmanParams,
    means: &mut [f64],
    covs: &mut [f64],
    y: f64,
) -> Vec<f64> {
    let n = means.len() / DZ;
    let mut lls = vec![0.0f64; n];
    batch_kalman_cpu_into(params, means, covs, y, &mut lls);
    lls
}

/// [`batch_kalman_cpu`] writing into a caller-provided log-lik window —
/// the allocation-free form the sharded coordinator uses per shard-local
/// run: each run hands its own `means`/`covs`/`out` sub-slices, and
/// because every particle's update is independent, any split of the
/// population into runs produces bitwise the same states and log-liks as
/// one whole-population call. `out.len()` must equal `means.len() / DZ`.
pub fn batch_kalman_cpu_into(
    params: &KalmanParams,
    means: &mut [f64],
    covs: &mut [f64],
    y: f64,
    out: &mut [f64],
) {
    let n = means.len() / DZ;
    assert_eq!(out.len(), n, "log-lik window must cover the batch");
    for i in 0..n {
        let mean = means[i * DZ..(i + 1) * DZ].to_vec();
        let mut cov = Mat::zeros(DZ, DZ);
        for r in 0..DZ {
            for c in 0..DZ {
                *cov.at_mut(r, c) = covs[i * DZ * DZ + r * DZ + c];
            }
        }
        let mut ks = KalmanState::new(mean, cov);
        ks.predict(&params.a, &[0.0; DZ], &params.q);
        let ll = ks.update(&params.c, &Mat::from_rows(&[&[params.r]]), &[y]);
        out[i] = ll;
        means[i * DZ..(i + 1) * DZ].copy_from_slice(&ks.mean);
        for r in 0..DZ {
            for c in 0..DZ {
                covs[i * DZ * DZ + r * DZ + c] = ks.cov.at(r, c);
            }
        }
    }
}

/// Chunked executor for the compiled batched-Kalman artifact.
pub struct BatchKalman {
    artifact: Artifact,
}

impl BatchKalman {
    /// Load `kalman3.hlo.txt` from the runtime's artifact directory.
    pub fn load(rt: &XlaRuntime) -> Result<Self> {
        Ok(BatchKalman {
            artifact: rt.load("kalman3")?,
        })
    }

    /// Run predict+update+weight over all particles (padded chunks of
    /// [`BATCH`]); mutates means/covs in place, returns log-liks.
    pub fn run(&self, means: &mut [f64], covs: &mut [f64], y: f64) -> Result<Vec<f64>> {
        let n = means.len() / DZ;
        let mut lls = vec![0.0f64; n];
        let mut m32 = vec![0.0f32; BATCH * DZ];
        let mut p32 = vec![0.0f32; BATCH * DZ * DZ];
        let y32 = vec![y as f32; BATCH];
        let mut start = 0;
        while start < n {
            let end = (start + BATCH).min(n);
            let len = end - start;
            for i in 0..len {
                for d in 0..DZ {
                    m32[i * DZ + d] = means[(start + i) * DZ + d] as f32;
                }
                for d in 0..DZ * DZ {
                    p32[i * DZ * DZ + d] = covs[(start + i) * DZ * DZ + d] as f32;
                }
            }
            // Pad the tail with identity-ish state (results discarded).
            for i in len..BATCH {
                for d in 0..DZ {
                    m32[i * DZ + d] = 0.0;
                }
                for d in 0..DZ * DZ {
                    p32[i * DZ * DZ + d] = if d % (DZ + 1) == 0 { 1.0 } else { 0.0 };
                }
            }
            let out = self.artifact.run_f32(&[
                (&m32, &[BATCH as i64, DZ as i64]),
                (&p32, &[BATCH as i64, DZ as i64, DZ as i64]),
                (&y32, &[BATCH as i64]),
            ])?;
            let (new_m, new_p, ll) = (&out[0], &out[1], &out[2]);
            for i in 0..len {
                for d in 0..DZ {
                    means[(start + i) * DZ + d] = new_m[i * DZ + d] as f64;
                }
                for d in 0..DZ * DZ {
                    covs[(start + i) * DZ * DZ + d] = new_p[i * DZ * DZ + d] as f64;
                }
                lls[start + i] = ll[i] as f64;
            }
            start = end;
        }
        Ok(lls)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn init_batch(n: usize) -> (Vec<f64>, Vec<f64>) {
        let mut means = vec![0.0; n * DZ];
        let mut covs = vec![0.0; n * DZ * DZ];
        for i in 0..n {
            for d in 0..DZ {
                means[i * DZ + d] = (i as f64 * 0.1) + d as f64 * 0.01;
                covs[i * DZ * DZ + d * DZ + d] = 1.0 + 0.001 * i as f64;
            }
        }
        (means, covs)
    }

    #[test]
    fn cpu_batch_matches_single_state() {
        let params = KalmanParams::rbpf_default();
        let (mut means, mut covs) = init_batch(4);
        let singles: Vec<KalmanState> = (0..4)
            .map(|i| {
                let mean = means[i * DZ..(i + 1) * DZ].to_vec();
                let mut cov = Mat::zeros(DZ, DZ);
                for r in 0..DZ {
                    for c in 0..DZ {
                        *cov.at_mut(r, c) = covs[i * DZ * DZ + r * DZ + c];
                    }
                }
                KalmanState::new(mean, cov)
            })
            .collect();
        let lls = batch_kalman_cpu(&params, &mut means, &mut covs, 0.7);
        for (i, mut ks) in singles.into_iter().enumerate() {
            ks.predict(&params.a, &[0.0; DZ], &params.q);
            let ll = ks.update(&params.c, &Mat::from_rows(&[&[params.r]]), &[0.7]);
            assert!((lls[i] - ll).abs() < 1e-12);
            for d in 0..DZ {
                assert!((means[i * DZ + d] - ks.mean[d]).abs() < 1e-12);
            }
        }
    }

    /// Splitting the population into shard-local windows is bitwise the
    /// whole-batch call — the property the shard-aware runtime dispatch
    /// rests on (each shard runs the oracle over its own runs).
    #[test]
    fn cpu_batch_shard_split_bitwise_invariant() {
        let params = KalmanParams::rbpf_default();
        let n = 23;
        let (whole_m, whole_c) = init_batch(n);
        let mut ref_m = whole_m.clone();
        let mut ref_c = whole_c.clone();
        let ref_ll = batch_kalman_cpu(&params, &mut ref_m, &mut ref_c, 0.7);
        for k in [1usize, 2, 3, 5, 23] {
            let mut m = whole_m.clone();
            let mut c = whole_c.clone();
            let mut ll = vec![0.0f64; n];
            // K contiguous windows, like K shard-local runs.
            let per = n.div_ceil(k);
            let mut at = 0;
            while at < n {
                let end = (at + per).min(n);
                batch_kalman_cpu_into(
                    &params,
                    &mut m[at * DZ..end * DZ],
                    &mut c[at * DZ * DZ..end * DZ * DZ],
                    0.7,
                    &mut ll[at..end],
                );
                at = end;
            }
            for i in 0..n {
                assert_eq!(ll[i].to_bits(), ref_ll[i].to_bits(), "ll[{i}] k={k}");
            }
            for (a, b) in m.iter().zip(&ref_m) {
                assert_eq!(a.to_bits(), b.to_bits(), "means k={k}");
            }
            for (a, b) in c.iter().zip(&ref_c) {
                assert_eq!(a.to_bits(), b.to_bits(), "covs k={k}");
            }
        }
    }

    /// XLA artifact agrees with the CPU oracle (skips if not built).
    #[test]
    fn xla_matches_cpu_oracle() {
        let rt = XlaRuntime::cpu(super::super::tests::artifacts_dir()).unwrap();
        if !rt.has_artifact("kalman3") {
            eprintln!("skipping: kalman3 artifact not built");
            return;
        }
        let bk = BatchKalman::load(&rt).unwrap();
        let params = KalmanParams::rbpf_default();
        let n = BATCH + 37; // exercise padding
        let (mut m_xla, mut p_xla) = init_batch(n);
        let (mut m_cpu, mut p_cpu) = (m_xla.clone(), p_xla.clone());
        let ll_xla = bk.run(&mut m_xla, &mut p_xla, 0.9).unwrap();
        let ll_cpu = batch_kalman_cpu(&params, &mut m_cpu, &mut p_cpu, 0.9);
        for i in 0..n {
            assert!(
                (ll_xla[i] - ll_cpu[i]).abs() < 1e-3,
                "ll[{i}]: {} vs {}",
                ll_xla[i],
                ll_cpu[i]
            );
            for d in 0..DZ {
                assert!(
                    (m_xla[i * DZ + d] - m_cpu[i * DZ + d]).abs() < 1e-3,
                    "mean[{i},{d}]"
                );
            }
        }
    }
}
