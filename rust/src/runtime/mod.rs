//! PJRT runtime: load and execute AOT-compiled XLA artifacts.
//!
//! The build-time Python layer (`python/compile/aot.py`) lowers the JAX/
//! Pallas numeric step functions to **HLO text** (the interchange format —
//! xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id serialized protos) into
//! `artifacts/*.hlo.txt`. With the `xla` cargo feature enabled (requires
//! vendoring the `xla` crate and its `libxla_extension` runtime), this
//! module compiles them once on a PJRT CPU client and executes them from
//! the coordinator's hot path; Python never runs at inference time.
//!
//! The default build is **dependency-free**: a stub with the identical API
//! reports no artifacts, so every caller falls back to the f64 CPU oracle
//! path ([`batch_kalman_cpu`]) — which is also the reference the artifact
//! is differentially tested against.
//!
//! Artifacts are lowered for a fixed batch size [`BATCH`]; the runtime
//! processes particle populations in padded chunks.

mod kalman;

pub use kalman::{batch_kalman_cpu, batch_kalman_cpu_into, BatchKalman, KalmanParams, DZ};

/// Batch size artifacts are lowered with (must match `python/compile/aot.py`).
pub const BATCH: usize = 256;

/// Runtime error type (local, so the crate stays dependency-free).
#[derive(Debug)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Runtime result alias.
pub type Result<T> = std::result::Result<T, RuntimeError>;

#[cfg(feature = "xla")]
mod pjrt {
    //! Real PJRT-backed implementation (feature `xla`).
    use super::{Result, RuntimeError};
    use std::path::{Path, PathBuf};

    /// A compiled XLA executable loaded from HLO text.
    pub struct Artifact {
        exe: xla::PjRtLoadedExecutable,
        /// Artifact name (file stem).
        pub name: String,
    }

    /// PJRT CPU client + artifact loader.
    pub struct XlaRuntime {
        client: xla::PjRtClient,
        dir: PathBuf,
    }

    impl XlaRuntime {
        /// Create a CPU runtime reading artifacts from `dir`.
        pub fn cpu(dir: impl AsRef<Path>) -> Result<Self> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| RuntimeError(format!("create PJRT CPU client: {e}")))?;
            Ok(XlaRuntime {
                client,
                dir: dir.as_ref().to_path_buf(),
            })
        }

        /// PJRT platform name (e.g. `cpu`).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Path the artifact `name` would be loaded from.
        pub fn artifact_path(&self, name: &str) -> PathBuf {
            self.dir.join(format!("{name}.hlo.txt"))
        }

        /// Whether the artifact exists on disk.
        pub fn has_artifact(&self, name: &str) -> bool {
            self.artifact_path(name).exists()
        }

        /// Load and compile an artifact by name (`artifacts/<name>.hlo.txt`).
        pub fn load(&self, name: &str) -> Result<Artifact> {
            let path = self.artifact_path(name);
            let path_str = path
                .to_str()
                .ok_or_else(|| RuntimeError("non-utf8 path".into()))?;
            let proto = xla::HloModuleProto::from_text_file(path_str)
                .map_err(|e| RuntimeError(format!("parse HLO text {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| RuntimeError(format!("compile artifact {name}: {e}")))?;
            Ok(Artifact {
                exe,
                name: name.to_string(),
            })
        }
    }

    impl Artifact {
        /// Execute with f32 inputs of the given shapes; returns the
        /// flattened f32 outputs (the jax side lowers with
        /// `return_tuple=True`).
        pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, dims) in inputs {
                let lit = xla::Literal::vec1(data);
                let lit = if dims.len() == 1 && dims[0] as usize == data.len() {
                    lit
                } else {
                    lit.reshape(dims)
                        .map_err(|e| RuntimeError(format!("reshape input to {dims:?}: {e}")))?
                };
                literals.push(lit);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| RuntimeError(format!("execute {}: {e}", self.name)))?[0][0]
                .to_literal_sync()
                .map_err(|e| RuntimeError(format!("fetch result: {e}")))?;
            let parts = result
                .to_tuple()
                .map_err(|e| RuntimeError(format!("untuple result: {e}")))?;
            let mut out = Vec::with_capacity(parts.len());
            for p in parts {
                out.push(
                    p.to_vec::<f32>()
                        .map_err(|e| RuntimeError(format!("read f32 output: {e}")))?,
                );
            }
            Ok(out)
        }
    }
}

#[cfg(not(feature = "xla"))]
mod pjrt {
    //! Dependency-free stub (the default build). Same API surface;
    //! reports no artifacts so every caller takes the CPU oracle path.
    use super::{Result, RuntimeError};
    use std::path::{Path, PathBuf};

    /// Placeholder for a compiled executable; cannot be constructed
    /// without the `xla` feature.
    pub struct Artifact {
        /// Artifact name (file stem).
        pub name: String,
    }

    /// Stub runtime: comes up, but exposes no artifacts.
    pub struct XlaRuntime {
        dir: PathBuf,
    }

    impl XlaRuntime {
        /// Create a stub runtime reading artifacts from `dir` (never
        /// fails; artifacts are simply reported absent).
        pub fn cpu(dir: impl AsRef<Path>) -> Result<Self> {
            Ok(XlaRuntime {
                dir: dir.as_ref().to_path_buf(),
            })
        }

        /// Stub platform name.
        pub fn platform(&self) -> String {
            "cpu-stub (xla feature disabled)".to_string()
        }

        /// Path the artifact `name` would be loaded from.
        pub fn artifact_path(&self, name: &str) -> PathBuf {
            self.dir.join(format!("{name}.hlo.txt"))
        }

        /// Always false: without the `xla` feature an artifact on disk
        /// cannot be executed, so it is reported as absent and callers
        /// fall back to the CPU oracle.
        pub fn has_artifact(&self, _name: &str) -> bool {
            false
        }

        /// Always an error: nothing can be executed without `xla`.
        pub fn load(&self, name: &str) -> Result<Artifact> {
            Err(RuntimeError(format!(
                "XLA/PJRT support not compiled in (enable the `xla` feature); \
                 cannot load artifact {name}"
            )))
        }
    }

    impl Artifact {
        /// Always an error: nothing can be executed without `xla`.
        pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
            Err(RuntimeError(
                "XLA/PJRT support not compiled in (enable the `xla` feature)".into(),
            ))
        }
    }
}

pub use pjrt::{Artifact, XlaRuntime};

#[cfg(test)]
mod tests {
    use super::*;

    #[allow(dead_code)]
    pub(crate) fn artifacts_dir() -> std::path::PathBuf {
        // Tests run from the crate root.
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn client_comes_up() {
        let rt = XlaRuntime::cpu("artifacts").expect("runtime client");
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn missing_artifact_is_reported() {
        let rt = XlaRuntime::cpu("artifacts").unwrap();
        assert!(!rt.has_artifact("definitely_not_there"));
        assert!(rt.load("definitely_not_there").is_err());
    }

    /// Full round trip when the build has produced artifacts and the
    /// `xla` feature is enabled (skips otherwise; `make artifacts`
    /// creates them).
    #[test]
    fn logpdf_artifact_round_trip() {
        let rt = XlaRuntime::cpu(artifacts_dir()).unwrap();
        if !rt.has_artifact("logpdf") {
            eprintln!("skipping: artifacts not built or xla feature disabled");
            return;
        }
        let art = rt.load("logpdf").unwrap();
        let n = BATCH;
        let x: Vec<f32> = (0..n).map(|i| i as f32 * 0.01).collect();
        let mean = vec![0.5f32; n];
        let sd = vec![2.0f32; n];
        let out = art
            .run_f32(&[
                (&x, &[n as i64]),
                (&mean, &[n as i64]),
                (&sd, &[n as i64]),
            ])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), n);
        for i in 0..n {
            let want = crate::rng::normal_lpdf(x[i] as f64, 0.5, 2.0);
            assert!(
                (out[0][i] as f64 - want).abs() < 1e-4,
                "i={i}: {} vs {want}",
                out[0][i]
            );
        }
    }
}
