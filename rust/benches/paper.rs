//! `cargo bench` — regenerates every table and figure of the paper's
//! evaluation (§4) at the default reduced scale, plus the ablations and
//! the platform micro-benchmarks used by the §Perf pass.
//!
//! Sections (select with LAZYCOW_BENCH=fig5,fig6,... ; default: all):
//!   fig5       inference task: execution time + peak memory, 3 configs × 5 problems
//!   fig6       simulation task: lazy-pointer overhead isolation
//!   fig7       per-generation time/memory series (eager quadratic vs lazy linear)
//!   ablation   single-reference optimization on/off (Remark 1)
//!   treebound  ancestry reachability vs t + c·N·log N (Jacob et al. 2015)
//!   micro      heap hot-path micro-benchmarks (deep_copy / pull / get)
//!   shards     shard-count sweep (K = 1, 2, 4, 8) with per-K JSON records
//!   rebalance  rebalance-policy sweep (off/greedy/budget, K = 4) on the
//!              skewed PCFG workload, JSON per cell
//!   alloc      payload-allocator sweep (system vs slab) on the
//!              resampling-churn workloads (VBD, PCFG), JSON per cell,
//!              plus the long-run churn cell asserting committed
//!              residency stays bounded with decommit on (and monotone
//!              with it off)
//!   batch      batched SoA numeric path: fused weight reduction vs the
//!              scalar three-pass sequence, and step_batched propagation
//!              throughput vs the scalar per-particle reference (LGSS +
//!              RBPF, K = 1, 2, 4), bitwise identity asserted per cell
//!   session    resumable FilterSession engine: driver-vs-session bitwise
//!              identity, per-generation step latency, fork cost vs
//!              stepped history depth (flat — O(particles), not O(heap)),
//!              and lazy fork vs eager whole-population copy
//!   observability  `--trace` span-recorder overhead (LGSS + PCFG at
//!              K = 4, tracing off vs on, bitwise identity asserted) and
//!              the cost of rendering a populated telemetry registry
//!              into the Prometheus exposition format
//!   heapev     heap-evolution cells: per-barrier trim cost must be flat
//!              in the number of free-list blocks (the per-chunk live
//!              counters make the empty-chunk scan O(chunks)), and the
//!              evacuating-defrag cell — a sparse allocation spike whose
//!              survivors compact into bump space with bit-identical
//!              values and strictly lower committed residency
//!
//! Environment: LAZYCOW_REPS (default 5), LAZYCOW_SCALE=default|paper.

use lazycow::bench::{human_bytes, run_cell, CellResult};
use lazycow::config::{Model, RunConfig, Task};
use lazycow::heap::{CopyMode, Heap, Lazy, ShardedHeap};
use lazycow::lazy_fields;
use lazycow::models::{run_model, ListModel, Rbpf, DATA_SEED};
use lazycow::pool::ThreadPool;
use lazycow::runtime::{BatchKalman, XlaRuntime};
use lazycow::smc::{particle_rng, run_filter, run_filter_shards, FilterSession, Method, SmcModel, StepCtx};
use lazycow::stats::median_iqr;

fn sections() -> Vec<String> {
    match std::env::var("LAZYCOW_BENCH") {
        Ok(s) if !s.is_empty() => s.split(',').map(|x| x.trim().to_string()).collect(),
        _ => [
            "fig5",
            "fig6",
            "fig7",
            "ablation",
            "treebound",
            "micro",
            "functional",
            "resamplers",
            "shards",
            "rebalance",
            "alloc",
            "batch",
            "session",
            "observability",
            "heapev",
        ]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    }
}

fn reps() -> usize {
    std::env::var("LAZYCOW_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5)
}

fn paper_scale() -> bool {
    std::env::var("LAZYCOW_SCALE").map(|v| v == "paper").unwrap_or(false)
}

struct Backend {
    pool: ThreadPool,
    kalman: Option<BatchKalman>,
}

impl Backend {
    fn new() -> Self {
        let kalman = XlaRuntime::cpu("artifacts")
            .ok()
            .filter(|rt| rt.has_artifact("kalman3"))
            .and_then(|rt| BatchKalman::load(&rt).ok());
        if kalman.is_some() {
            eprintln!("[bench] using compiled kalman3 artifact");
        } else {
            eprintln!("[bench] artifacts missing; CPU oracle path");
        }
        Backend {
            pool: ThreadPool::new(0),
            kalman,
        }
    }

    fn ctx(&self) -> StepCtx<'_> {
        StepCtx {
            pool: &self.pool,
            kalman: self.kalman.as_ref(),
            batch: true,
        }
    }
}

fn figure_cells(task: Task, backend: &Backend) -> Vec<CellResult> {
    let mut cells = Vec::new();
    for model in Model::EVAL {
        for mode in CopyMode::ALL {
            let mut cfg = RunConfig::for_model(model, task, mode);
            if paper_scale() {
                let (n, t_inf, t_sim) = model.paper_scale();
                cfg.n_particles = n;
                cfg.n_steps = if task == Task::Inference { t_inf } else { t_sim };
            }
            let name = format!("{}/{}", model.name(), mode.name());
            let cell = run_cell(&name, reps(), |rep| {
                let mut c = cfg.clone();
                c.seed = 20200401u64.wrapping_add(rep as u64);
                // K = 1: the paper's serialized-heap baseline (the shard
                // sweep section measures K > 1).
                let mut heap = ShardedHeap::new(c.mode, 1);
                let r = run_model(&c, &mut heap, &backend.ctx());
                Some(r.peak_bytes as f64)
            });
            println!("  {}", cell.pretty_row());
            cells.push(cell);
        }
    }
    cells
}

fn summarize_ratios(cells: &[CellResult]) {
    // Per problem: eager/lazy-sro ratios (the paper's headline contrast).
    for chunk in cells.chunks(3) {
        let problem = chunk[0].name.split('/').next().unwrap();
        let t_ratio = chunk[0].time_median / chunk[2].time_median.max(1e-9);
        let m_ratio = chunk[0].mem_median.unwrap_or(0.0) / chunk[2].mem_median.unwrap_or(1.0);
        println!(
            "  {problem:<6} eager/lazy-sro: time x{:.2}, peak-mem x{:.2}",
            t_ratio, m_ratio
        );
    }
}

fn bench_fig5(backend: &Backend) {
    println!("\n== Figure 5: inference task (time + peak memory, median [IQR]) ==");
    let cells = figure_cells(Task::Inference, backend);
    println!("-- ratios --");
    summarize_ratios(&cells);
}

fn bench_fig6(backend: &Backend) {
    println!("\n== Figure 6: simulation task (no copies; lazy-pointer overhead) ==");
    let cells = figure_cells(Task::Simulation, backend);
    println!("-- ratios (expected ~1.0 time, slight memory overhead for lazy) --");
    summarize_ratios(&cells);
}

fn bench_fig7(backend: &Backend) {
    println!("\n== Figure 7: elapsed time and memory across t (inference) ==");
    for model in Model::EVAL {
        println!("-- {} --", model.name());
        println!("  mode       t=¼T        t=½T        t=¾T        t=T         (elapsed s | live bytes)");
        for mode in CopyMode::ALL {
            let cfg = RunConfig::for_model(model, Task::Inference, mode);
            let mut heap = ShardedHeap::new(mode, 1);
            let r = run_model(&cfg, &mut heap, &backend.ctx());
            let quarter = |f: f64| {
                let idx = ((r.series.len() as f64 * f) as usize).min(r.series.len() - 1);
                let s = &r.series[idx];
                format!("{:.2}s|{}", s.elapsed_s, human_bytes(s.live_bytes as f64))
            };
            println!(
                "  {:<9} {:>12} {:>12} {:>12} {:>12}",
                mode.name(),
                quarter(0.25),
                quarter(0.5),
                quarter(0.75),
                quarter(1.0)
            );
        }
    }
}

fn bench_ablation(backend: &Backend) {
    println!("\n== Ablation: single-reference optimization (Remark 1) ==");
    // Compare lazy vs lazy-sro on the problems with per-object write
    // traffic (PCFG in-place stacks, MOT track arrays) and report memo
    // traffic removed.
    for model in [Model::Pcfg, Model::Mot, Model::Rbpf] {
        for mode in [CopyMode::Lazy, CopyMode::LazySro] {
            let cfg = RunConfig::for_model(model, Task::Inference, mode);
            let mut heap = ShardedHeap::new(mode, 1);
            let start = std::time::Instant::now();
            let r = run_model(&cfg, &mut heap, &backend.ctx());
            let m = heap.metrics();
            println!(
                "  {:<5} {:<9} wall {:.3}s  peak {:>10}  memo-inserts avoided {:>8}  memo bytes {:>10}",
                model.name(),
                mode.name(),
                start.elapsed().as_secs_f64(),
                human_bytes(r.peak_bytes as f64),
                m.sro_skips,
                human_bytes(m.memo_bytes as f64),
            );
        }
    }
}

fn bench_treebound() {
    println!("\n== Ancestry tree: reachable objects vs t + 2N·lnN (Jacob et al. 2015) ==");
    let n = 256;
    for t_max in [50usize, 100, 200, 400] {
        let model = ListModel::synthetic(t_max, DATA_SEED);
        let mut cfg = RunConfig::for_model(Model::List, Task::Inference, CopyMode::LazySro);
        cfg.n_particles = n;
        cfg.n_steps = t_max;
        let pool = ThreadPool::new(1);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        let mut heap = Heap::new(CopyMode::LazySro);
        let r = run_filter(&model, &cfg, &mut heap, &ctx, Method::Bootstrap);
        let live = r.series.last().unwrap().live_objects;
        let bound = t_max as f64 + 2.0 * n as f64 * (n as f64).ln();
        println!(
            "  T={t_max:<4} live={live:<6} bound={bound:<8.0} dense={:<8} sparse/dense = {:.3}",
            n * t_max,
            live as f64 / (n * t_max) as f64
        );
        assert!((live as f64) < bound, "Jacob et al. bound violated");
    }
}

#[derive(Clone)]
struct Node {
    #[allow(dead_code)]
    value: i64,
    next: Lazy<Node>,
}
lazy_fields!(Node: next);

fn bench_micro() {
    println!("\n== Heap hot-path micro-benchmarks ==");
    let build = |heap: &mut Heap, len: usize| -> Lazy<Node> {
        let mut head = heap.alloc(Node {
            value: 0,
            next: Lazy::NULL,
        });
        for i in 1..len {
            let new = heap.alloc(Node {
                value: i as i64,
                next: head,
            });
            heap.release(head);
            head = new;
        }
        head
    };

    // deep_copy cost (lazy): O(freeze on first, O(memo) after).
    let cell = run_cell("deep_copy_1k_chain (lazy-sro)", reps().max(5), |_| {
        let mut heap = Heap::new(CopyMode::LazySro);
        let head = build(&mut heap, 1000);
        let start = std::time::Instant::now();
        let mut copies = Vec::new();
        for _ in 0..1000 {
            copies.push(heap.deep_copy(&head));
        }
        let d = start.elapsed();
        for c in copies {
            heap.release(c);
        }
        heap.release(head);
        println!("    1000 deep copies of 1k-chain: {:.1} ns/copy", d.as_nanos() as f64 / 1000.0);
        None
    });
    println!("  {}", cell.pretty_row());

    // pull/read down a shared frozen chain.
    let cell = run_cell("read_chain_1k (lazy-sro)", reps().max(5), |_| {
        let mut heap = Heap::new(CopyMode::LazySro);
        let head = build(&mut heap, 1000);
        let copy = heap.deep_copy(&head);
        let mut sum = 0i64;
        let start = std::time::Instant::now();
        for _ in 0..100 {
            let mut cur = copy;
            while !cur.is_null() {
                sum += heap.read(&mut cur, |n| n.value);
                cur = heap.read_ptr(&mut cur, |n| n.next);
            }
        }
        let d = start.elapsed();
        std::hint::black_box(sum);
        println!(
            "    chain reads: {:.1} ns/node",
            d.as_nanos() as f64 / (100.0 * 1000.0)
        );
        heap.release(copy);
        heap.release(head);
        None
    });
    println!("  {}", cell.pretty_row());

    // get (copy-on-write) down a chain.
    let cell = run_cell("cow_chain_256 (lazy-sro)", reps().max(5), |_| {
        let mut heap = Heap::new(CopyMode::LazySro);
        let head = build(&mut heap, 256);
        let start = std::time::Instant::now();
        for _ in 0..100 {
            let mut copy = heap.deep_copy(&head);
            heap.mutate_root(&mut copy, |n| n.value += 1);
            let mut cur = copy;
            for _ in 0..255 {
                cur = heap.get_field(&cur, |n| &mut n.next);
                heap.mutate(&mut cur, |n| n.value += 1);
            }
            heap.release(copy);
        }
        let d = start.elapsed();
        println!(
            "    full COW of 256-chain: {:.1} ns/node (copy+memo+rc)",
            d.as_nanos() as f64 / (100.0 * 256.0)
        );
        heap.release(head);
        None
    });
    println!("  {}", cell.pretty_row());
}

/// The paper's §5 "in-place write optimizations for the functional
/// programmer": an immutable-update loop (copy, modify, drop the old
/// version) where thaw/copy-elimination recycles the sole-referenced
/// object instead of allocating.
fn bench_functional() {
    println!("\n== Functional pattern: immutable updates with copy elimination ==");
    for mode in [CopyMode::Eager, CopyMode::Lazy, CopyMode::LazySro] {
        let mut heap = Heap::new(mode);
        let mut v = heap.alloc(Node {
            value: 0,
            next: Lazy::NULL,
        });
        let start = std::time::Instant::now();
        let iters = 200_000;
        for i in 0..iters {
            // v' = v with {value += i}; v dropped before the write — the
            // copy-elimination case: the frozen object has one reference.
            let mut next = heap.deep_copy(&v);
            heap.release(v);
            heap.mutate_root(&mut next, |n| n.value += i);
            v = next;
        }
        let d = start.elapsed();
        println!(
            "  {:<9} {:>8.1} ns/update   allocs={:<8} thaws={:<8} copies={}",
            mode.name(),
            d.as_nanos() as f64 / iters as f64,
            heap.metrics.total_allocs,
            heap.metrics.thaws,
            heap.metrics.lazy_copies + heap.metrics.eager_copies,
        );
        heap.release(v);
    }
    println!("  (lazy modes: thaw recycles the sole-referenced object in place)");
}

/// Shard-count sweep (the sharded-heap acceptance benchmark): wall time
/// and peak bytes per K on the VBD (particle Gibbs, the heap-mutation-
/// heavy workload) and RBPF (bootstrap + per-particle Kalman) models.
/// Emits one JSON record per (model, K) so successive PRs have a
/// machine-readable perf trajectory to beat. The K = 1 output is
/// bit-identical to the single-heap platform; K > 1 only changes where
/// heap work runs, never what is computed.
fn bench_shards(backend: &Backend) {
    println!("\n== Shard sweep: wall time / peak bytes vs K (JSON per cell) ==");
    let threads = backend.pool.n_threads();
    for model in [Model::Vbd, Model::Rbpf] {
        let mut baseline_evidence: Option<u64> = None;
        for k in [1usize, 2, 4, 8] {
            let mut cfg = RunConfig::for_model(model, Task::Inference, CopyMode::LazySro);
            if paper_scale() {
                let (n, t_inf, _) = model.paper_scale();
                cfg.n_particles = n;
                cfg.n_steps = t_inf;
            }
            cfg.shards = k;
            let n_particles = cfg.n_particles;
            let t_steps = cfg.n_steps;
            let mut transplants = 0usize;
            let mut evidence_bits = 0u64;
            let mut global_peak = 0usize;
            let cell = {
                let transplants = &mut transplants;
                let evidence_bits = &mut evidence_bits;
                let global_peak = &mut global_peak;
                run_cell(&format!("{}/K={k}", model.name()), reps(), move |rep| {
                    let mut c = cfg.clone();
                    c.seed = 20200401u64.wrapping_add(rep as u64);
                    let mut heap = ShardedHeap::new(c.mode, k);
                    let r = run_model(&c, &mut heap, &backend.ctx());
                    if rep == 0 {
                        *transplants = heap.metrics().transplants;
                        *evidence_bits = r.log_evidence.to_bits();
                        *global_peak = r.global_peak_bytes;
                    }
                    // The exact figure: continuous peak at K = 1, the
                    // barrier-sampled global peak at K > 1 (never the
                    // inflated sum of per-shard peaks).
                    Some(r.global_peak_bytes as f64)
                })
            };
            // K-invariance holds on the CPU oracle path; with a compiled
            // f32 artifact the K=1 cell runs it while K>1 shards use the
            // f64 oracle, so skip the bitwise check there.
            if backend.kalman.is_none() {
                match baseline_evidence {
                    None => baseline_evidence = Some(evidence_bits),
                    Some(b) => assert_eq!(
                        b, evidence_bits,
                        "{}: K={k} output differs from K=1",
                        model.name()
                    ),
                }
            }
            println!(
                "{{\"section\":\"shards\",\"model\":\"{}\",\"shards\":{},\"threads\":{},\"particles\":{},\"steps\":{},\"reps\":{},\"time_median_s\":{:.6},\"time_q1_s\":{:.6},\"time_q3_s\":{:.6},\"time_per_gen_s\":{:.6},\"global_peak_bytes_median\":{:.0},\"global_peak_bytes\":{},\"transplants\":{}}}",
                model.name(),
                k,
                threads,
                n_particles,
                t_steps,
                cell.reps,
                cell.time_median,
                cell.time_q1,
                cell.time_q3,
                cell.time_median / t_steps.max(1) as f64,
                cell.mem_median.unwrap_or(0.0),
                global_peak,
                transplants,
            );
        }
    }
}

/// Rebalance + steal sweep (the scheduling layer's acceptance benchmark):
/// wall time, exact global peak, migrations, transplants, and steals per
/// cell at K = 4, on *both* skewed workloads. PCFG (auxiliary PF — the
/// propagation paths work stealing applies to) sweeps policy × steal
/// on/off, so steal-on vs steal-off regressions and any output
/// divergence show up directly in CI logs. CRBD (alive PF, whose
/// per-particle cost tracks the inferred birth rate via retry-heavy
/// hidden-subtree simulation) sweeps policy only: its rounds executor
/// self-balances within a generation, so the steal flag is inert there
/// by design — what varies is the rebalancer acting on the rounds'
/// measured costs. Emits one JSON record per cell; outputs are asserted
/// bit-identical across every cell of a model, so the sweep measures
/// pure scheduling effect.
fn bench_rebalance(backend: &Backend) {
    use lazycow::smc::RebalancePolicy;
    println!(
        "\n== Rebalance sweep: policy × steal on skewed PCFG + CRBD (K = 4, JSON per cell) =="
    );
    let threads = backend.pool.n_threads();
    let k = 4usize;
    for model in [Model::Pcfg, Model::Crbd] {
        // The steal axis only exists on the stealing propagation paths;
        // the alive PF's rounds executor ignores it (see above).
        let steal_axis: &[bool] = if model == Model::Pcfg {
            &[false, true]
        } else {
            &[true]
        };
        let mut baseline_evidence: Option<u64> = None;
        let mut off_median: Option<f64> = None;
        for policy in RebalancePolicy::ALL {
            for &steal in steal_axis {
                let mut cfg = RunConfig::for_model(model, Task::Inference, CopyMode::LazySro);
                if paper_scale() {
                    let (n, t_inf, _) = model.paper_scale();
                    cfg.n_particles = n;
                    cfg.n_steps = t_inf;
                }
                cfg.shards = k;
                cfg.rebalance = policy;
                cfg.steal = steal;
                let n_particles = cfg.n_particles;
                let t_steps = cfg.n_steps;
                let mut migrations = 0usize;
                let mut steals = 0usize;
                let mut transplants = 0usize;
                let mut global_peak = 0usize;
                let mut scratch_peak = 0usize;
                let mut evidence_bits = 0u64;
                let steal_name = if steal { "on" } else { "off" };
                let cell = {
                    let migrations = &mut migrations;
                    let steals = &mut steals;
                    let transplants = &mut transplants;
                    let global_peak = &mut global_peak;
                    let scratch_peak = &mut scratch_peak;
                    let evidence_bits = &mut evidence_bits;
                    run_cell(
                        &format!("{}/{}/steal-{}", model.name(), policy.name(), steal_name),
                        reps(),
                        move |rep| {
                            let mut c = cfg.clone();
                            c.seed = 20200401u64.wrapping_add(rep as u64);
                            let mut heap = ShardedHeap::new(c.mode, k);
                            let r = run_model(&c, &mut heap, &backend.ctx());
                            if rep == 0 {
                                *migrations = r.migrations;
                                *steals = r.steals;
                                *transplants = heap.metrics().transplants;
                                *global_peak = r.global_peak_bytes;
                                *scratch_peak = r.scratch_peak_bytes;
                                *evidence_bits = r.log_evidence.to_bits();
                            }
                            Some(r.global_peak_bytes as f64)
                        },
                    )
                };
                match baseline_evidence {
                    None => baseline_evidence = Some(evidence_bits),
                    Some(b) => assert_eq!(
                        b,
                        evidence_bits,
                        "{}: policy {} / steal {} changed the output",
                        model.name(),
                        policy.name(),
                        steal_name
                    ),
                }
                // Baseline cell: policy off at the model's first steal
                // setting (steal-off for PCFG; CRBD has only one).
                if policy == RebalancePolicy::Off && steal == steal_axis[0] {
                    off_median = Some(cell.time_median);
                }
                println!(
                    "{{\"section\":\"rebalance\",\"model\":\"{}\",\"policy\":\"{}\",\"steal\":\"{}\",\"shards\":{},\"threads\":{},\"particles\":{},\"steps\":{},\"reps\":{},\"time_median_s\":{:.6},\"time_q1_s\":{:.6},\"time_q3_s\":{:.6},\"speedup_vs_off\":{:.4},\"global_peak_bytes\":{},\"scratch_peak_bytes\":{},\"migrations\":{},\"steals\":{},\"transplants\":{}}}",
                    model.name(),
                    policy.name(),
                    steal_name,
                    k,
                    threads,
                    n_particles,
                    t_steps,
                    cell.reps,
                    cell.time_median,
                    cell.time_q1,
                    cell.time_q3,
                    off_median.map(|o| o / cell.time_median.max(1e-9)).unwrap_or(1.0),
                    global_peak,
                    scratch_peak,
                    migrations,
                    steals,
                    transplants,
                );
            }
        }
    }
}

/// Payload-allocator sweep (the slab subsystem's acceptance benchmark):
/// system vs slab on the two resampling-churn workloads — VBD (particle
/// Gibbs: per-generation offspring copies + lineage releases) and PCFG
/// (auxiliary PF with `ess = 1.0`, resampling every generation). K = 1 so
/// the peak figure is exact and the allocator is the only variable.
/// Emits one JSON record per cell with allocation throughput, peak
/// bytes, and the slab gauges (free-list hit rate, chunks, committed
/// bytes, fragmentation at the fullest moment). Asserts the outputs are
/// bit-identical across backends and that the slab's free-list hit rate
/// is nonzero — resampling churn *must* recycle blocks, or the subsystem
/// is not doing its job.
fn bench_alloc(backend: &Backend) {
    use lazycow::heap::AllocatorKind;
    println!("\n== Allocator sweep: system vs slab on resampling churn (K = 1, JSON per cell) ==");
    let threads = backend.pool.n_threads();
    for model in [Model::Vbd, Model::Pcfg] {
        let mut baseline_evidence: Option<u64> = None;
        let mut system_median: Option<f64> = None;
        for kind in AllocatorKind::ALL {
            let mut cfg = RunConfig::for_model(model, Task::Inference, CopyMode::LazySro);
            if paper_scale() {
                let (n, t_inf, _) = model.paper_scale();
                cfg.n_particles = n;
                cfg.n_steps = t_inf;
            }
            cfg.shards = 1;
            cfg.allocator = kind;
            let n_particles = cfg.n_particles;
            let t_steps = cfg.n_steps;
            let cfg_decommit_off = cfg.clone();
            let mut evidence_bits = 0u64;
            let mut metrics = lazycow::heap::HeapMetrics::default();
            let mut peak = 0usize;
            let cell = {
                let evidence_bits = &mut evidence_bits;
                let metrics = &mut metrics;
                let peak = &mut peak;
                run_cell(
                    &format!("{}/alloc-{}", model.name(), kind.name()),
                    reps(),
                    move |rep| {
                        let mut c = cfg.clone();
                        c.seed = 20200401u64.wrapping_add(rep as u64);
                        let mut heap = ShardedHeap::with_allocator(c.mode, 1, kind);
                        let r = run_model(&c, &mut heap, &backend.ctx());
                        if rep == 0 {
                            *evidence_bits = r.log_evidence.to_bits();
                            *metrics = heap.metrics();
                            *peak = r.peak_bytes;
                        }
                        Some(r.peak_bytes as f64)
                    },
                )
            };
            match baseline_evidence {
                None => baseline_evidence = Some(evidence_bits),
                Some(b) => assert_eq!(
                    b,
                    evidence_bits,
                    "{}: allocator {} changed the output",
                    model.name(),
                    kind.name()
                ),
            }
            if kind == AllocatorKind::System {
                system_median = Some(cell.time_median);
            }
            if kind == AllocatorKind::Slab {
                assert!(
                    metrics.slab_freelist_hits > 0,
                    "{}: resampling churn produced no free-list reuse",
                    model.name()
                );
                // Decommit bit-identity: the same slab cell with the
                // watermark off must compute the same evidence — decommit
                // only changes where chunk memory lives.
                let mut c_off = cfg_decommit_off.clone();
                c_off.seed = 20200401u64;
                c_off.decommit_watermark = None;
                let mut heap = ShardedHeap::with_allocator(c_off.mode, 1, kind);
                let r_off = run_model(&c_off, &mut heap, &backend.ctx());
                assert_eq!(
                    r_off.log_evidence.to_bits(),
                    evidence_bits,
                    "{}: decommit-off changed the output",
                    model.name()
                );
                assert_eq!(heap.metrics().decommitted_chunks, 0);
            }
            let allocs_per_s = metrics.total_allocs as f64 / cell.time_median.max(1e-9);
            println!(
                "{{\"section\":\"alloc\",\"model\":\"{}\",\"allocator\":\"{}\",\"threads\":{},\"particles\":{},\"steps\":{},\"reps\":{},\"time_median_s\":{:.6},\"time_q1_s\":{:.6},\"time_q3_s\":{:.6},\"speedup_vs_system\":{:.4},\"total_allocs\":{},\"allocs_per_s\":{:.0},\"peak_bytes\":{},\"freelist_hits\":{},\"fresh_bumps\":{},\"large_allocs\":{},\"hit_rate\":{:.4},\"chunks\":{},\"committed_bytes\":{},\"fragmentation\":{:.4},\"raw_allocs\":{},\"raw_frees\":{},\"decommitted_chunks\":{},\"decommitted_bytes\":{}}}",
                model.name(),
                kind.name(),
                threads,
                n_particles,
                t_steps,
                cell.reps,
                cell.time_median,
                cell.time_q1,
                cell.time_q3,
                system_median
                    .map(|s| s / cell.time_median.max(1e-9))
                    .unwrap_or(1.0),
                metrics.total_allocs,
                allocs_per_s,
                peak,
                metrics.slab_freelist_hits,
                metrics.slab_fresh_bumps,
                metrics.slab_large_allocs,
                metrics.slab_hit_rate(),
                metrics.slab_chunks,
                metrics.slab_committed_bytes,
                metrics.slab_fragmentation(),
                metrics.slab_raw_allocs,
                metrics.slab_raw_frees,
                metrics.decommitted_chunks,
                metrics.decommitted_bytes,
            );
        }
    }
}

/// Long-run churn cell of the `alloc` section: alternating allocation
/// spikes and low-residency phases on one heap, decommit on (the default
/// keep-2 watermark) vs off. Asserts the decommit run's committed bytes
/// stay *bounded* — spike chunks are returned at the barriers, with
/// `decommitted_chunks > 0` — while the off run's committed bytes are
/// *monotone* (they equal the high-water mark forever). Emits one JSON
/// record per setting so the residency trajectory is machine-readable.
fn bench_alloc_churn() {
    use lazycow::heap::DEFAULT_DECOMMIT_WATERMARK;
    println!("\n== Allocator long-run churn: committed residency, decommit on vs off ==");
    let rounds = 40usize;
    for watermark in [Some(DEFAULT_DECOMMIT_WATERMARK), None] {
        let mut heap = Heap::new(CopyMode::LazySro);
        let mut peak_committed = 0usize;
        let mut final_committed = 0usize;
        let start = std::time::Instant::now();
        for round in 0..rounds {
            // A spike every 8 rounds commits an order of magnitude more
            // chunks than the steady state needs.
            let spike = if round % 8 == 0 { 3000 } else { 100 };
            let mut roots = Vec::new();
            for i in 0..spike {
                let mut head = heap.alloc(Node {
                    value: i as i64,
                    next: Lazy::NULL,
                });
                for j in 1..8 {
                    let new = heap.alloc(Node {
                        value: j,
                        next: head,
                    });
                    heap.release(head);
                    head = new;
                }
                roots.push(head);
            }
            for r in roots {
                heap.release(r);
            }
            heap.sweep_memos();
            if let Some(w) = watermark {
                heap.trim(w);
            }
            peak_committed = peak_committed.max(heap.metrics.slab_committed_bytes);
            final_committed = heap.metrics.slab_committed_bytes;
        }
        let m = heap.metrics;
        let name = if watermark.is_some() { "on" } else { "off" };
        match watermark {
            Some(_) => {
                assert!(
                    m.decommitted_chunks > 0,
                    "spiky churn past the watermark must decommit chunks"
                );
                assert!(
                    final_committed < peak_committed,
                    "decommit on: committed bytes must drop back after spikes \
                     ({final_committed} vs peak {peak_committed})"
                );
            }
            None => {
                assert_eq!(m.decommitted_chunks, 0);
                assert_eq!(
                    final_committed, peak_committed,
                    "decommit off: committed bytes are monotone"
                );
            }
        }
        println!(
            "{{\"section\":\"alloc\",\"cell\":\"churn\",\"decommit\":\"{}\",\"rounds\":{},\"wall_s\":{:.4},\"peak_committed_bytes\":{},\"final_committed_bytes\":{},\"decommitted_chunks\":{},\"decommitted_bytes\":{},\"freelist_hits\":{},\"raw_allocs\":{}}}",
            name,
            rounds,
            start.elapsed().as_secs_f64(),
            peak_committed,
            final_committed,
            m.decommitted_chunks,
            m.decommitted_bytes,
            m.slab_freelist_hits,
            m.slab_raw_allocs,
        );
    }
}

/// `heapev` cell 1: per-barrier trim cost versus free-list population.
/// Two identical 64-chunk heaps are loaded with the same allocation
/// spike; one then frees 10% of its blocks, the other 90% (spread so no
/// chunk ever empties — every barrier is a pure liveness scan). The
/// per-chunk live counters make the empty-chunk scan O(chunks), so the
/// per-barrier cost must not grow with the free-block count: the 90%/10%
/// cost ratio is asserted ≤ 3× here and gated again by
/// tools/bench_check on the emitted `trim-flat` record.
fn bench_heapev_trim() {
    use lazycow::heap::{CHUNK_BYTES, DEFAULT_DECOMMIT_WATERMARK};
    println!("\n== Heap evolution: trim cost vs free-list population ==");
    let chunks = 64usize;
    let per_chunk = CHUNK_BYTES / 16; // Node is a 16-byte payload
    let total = chunks * per_chunk;
    let barriers = 4000usize;
    let mut per_barrier_us = Vec::new();
    for freed_tenths in [1usize, 9] {
        let mut heap = Heap::new(CopyMode::LazySro);
        let mut roots = Vec::with_capacity(total);
        for i in 0..total {
            roots.push(heap.alloc(Node {
                value: i as i64,
                next: Lazy::NULL,
            }));
        }
        // Free the fraction only after the whole spike is allocated, so
        // the free lists really hold `freed` blocks at every barrier
        // (freeing inline would let the allocator recycle them and keep
        // the lists near-empty).
        let mut freed = 0usize;
        let mut keep = Vec::new();
        for (i, r) in roots.into_iter().enumerate() {
            if i % 10 < freed_tenths {
                heap.release(r);
                freed += 1;
            } else {
                keep.push(r);
            }
        }
        heap.sweep_memos();
        // One warmup barrier absorbs any one-off reclamation (transient
        // raw chunks, LOS free-list trim) before the timed pure scans.
        heap.trim(DEFAULT_DECOMMIT_WATERMARK);
        let committed = heap.metrics.slab_committed_bytes;
        let start = std::time::Instant::now();
        for _ in 0..barriers {
            heap.trim(DEFAULT_DECOMMIT_WATERMARK);
        }
        let us = start.elapsed().as_secs_f64() * 1e6 / barriers as f64;
        per_barrier_us.push(us);
        // The freed pattern is spread evenly, so no chunk emptied and no
        // barrier decommitted anything: the loop timed scans only.
        assert_eq!(
            heap.metrics.slab_committed_bytes, committed,
            "trim-cost barriers must be pure scans"
        );
        heap.validate_storage();
        println!(
            "{{\"section\":\"heapev\",\"cell\":\"trim-cost\",\"freed_fraction\":0.{},\"free_blocks\":{},\"chunks\":{},\"barriers\":{},\"per_barrier_us\":{:.4}}}",
            freed_tenths,
            freed,
            committed / CHUNK_BYTES,
            barriers,
            us,
        );
        for r in keep {
            heap.release(r);
        }
    }
    let ratio = per_barrier_us[1] / per_barrier_us[0];
    assert!(
        ratio <= 3.0,
        "trim must be flat in free blocks: 90%-freed barrier cost {:.3}us \
         vs 10%-freed {:.3}us (ratio {ratio:.2})",
        per_barrier_us[1],
        per_barrier_us[0],
    );
    println!("{{\"section\":\"heapev\",\"cell\":\"trim-flat\",\"ratio\":{ratio:.4}}}");
}

/// `heapev` cell 2: evacuating defrag on an engineered sparse heap. A
/// 64-chunk allocation spike keeps one node in every 512 — eight
/// survivors per chunk, enough to pin every chunk committed forever
/// without evacuation. With `evacuate(0.5)` at the barrier the
/// survivors placement-move into shared bump space, the emptied chunks
/// decommit, and the survivors' values must still read back
/// bit-identical to the no-evacuation run.
fn bench_heapev_evacuate() {
    use lazycow::heap::{CHUNK_BYTES, DEFAULT_DECOMMIT_WATERMARK};
    println!("\n== Heap evolution: evacuating defrag on a sparse spike ==");
    let chunks = 64usize;
    let per_chunk = CHUNK_BYTES / 16;
    let total = chunks * per_chunk;
    let mut sums = Vec::new();
    let mut committed = Vec::new();
    let mut records = Vec::new();
    for evacuate in [false, true] {
        let mut heap = Heap::new(CopyMode::LazySro);
        let start = std::time::Instant::now();
        let mut roots = Vec::with_capacity(total);
        for i in 0..total {
            roots.push(heap.alloc(Node {
                value: i as i64,
                next: Lazy::NULL,
            }));
        }
        let mut survivors = Vec::new();
        for (i, r) in roots.into_iter().enumerate() {
            if i % 512 == 0 {
                survivors.push(r);
            } else {
                heap.release(r);
            }
        }
        heap.sweep_memos();
        let moved = if evacuate { heap.evacuate(0.5) } else { 0 };
        heap.trim(DEFAULT_DECOMMIT_WATERMARK);
        let wall = start.elapsed().as_secs_f64();
        let mut sum = 0i64;
        for s in survivors.iter_mut() {
            sum = sum.wrapping_add(heap.read(s, |n| n.value));
        }
        heap.validate_storage();
        let m = heap.metrics;
        if evacuate {
            assert!(moved > 0, "the sparse spike must trigger evacuation");
            assert_eq!(m.evacuated_objects, moved);
            assert!(
                m.evacuated_chunks >= 1,
                "evacuation must recycle at least one chunk"
            );
        } else {
            assert_eq!(m.evacuated_objects, 0);
            assert_eq!(m.evacuated_chunks, 0);
        }
        sums.push(sum);
        committed.push(m.slab_committed_bytes);
        records.push(format!(
            "{{\"section\":\"heapev\",\"cell\":\"evacuate\",\"evacuate\":\"{}\",\"survivors\":{},\"wall_s\":{:.4},\"evacuated_objects\":{},\"evacuated_chunks\":{},\"committed_bytes\":{},\"bit_identical\":BIT}}",
            if evacuate { "on" } else { "off" },
            survivors.len(),
            wall,
            m.evacuated_objects,
            m.evacuated_chunks,
            m.slab_committed_bytes,
        ));
        for s in survivors {
            heap.release(s);
        }
    }
    assert_eq!(
        sums[0], sums[1],
        "evacuation changed a survivor value: off-sum {} vs on-sum {}",
        sums[0], sums[1]
    );
    assert!(
        committed[1] < committed[0],
        "evacuation must lower committed residency ({} vs {})",
        committed[1],
        committed[0]
    );
    for rec in records {
        println!("{}", rec.replace("BIT", "true"));
    }
}

/// Pre-flight for the batch section: `step_batched` must match the
/// scalar `step_population` reference bit for bit on a small population
/// (run on the CPU-oracle context — the f32 artifact path is held to
/// tolerance by the integration suite instead).
fn assert_batched_matches_scalar<M: SmcModel + Sync>(model: &M, t_max: usize, ctx: &StepCtx) {
    let n = 96usize;
    let mut heap_a = Heap::new(CopyMode::LazySro);
    let mut heap_b = Heap::new(CopyMode::LazySro);
    let mut sa: Vec<Lazy<M::State>> = (0..n)
        .map(|i| model.init(&mut heap_a, &mut particle_rng(11, 0, i)))
        .collect();
    let mut sb: Vec<Lazy<M::State>> = (0..n)
        .map(|i| model.init(&mut heap_b, &mut particle_rng(11, 0, i)))
        .collect();
    for t in 1..=t_max {
        let wa = model
            .step_batched(&mut heap_a, &mut sa, t, 11, true, 0, ctx)
            .expect("model must batch inference");
        let wb = model.step_population(&mut heap_b, &mut sb, t, 11, true, 0, ctx);
        for i in 0..n {
            assert_eq!(
                wa[i].to_bits(),
                wb[i].to_bits(),
                "{}: batched/scalar diverged at t={t} i={i}",
                model.name()
            );
        }
    }
    for h in sa {
        heap_a.release(h);
    }
    for h in sb {
        heap_b.release(h);
    }
}

/// One propagation-throughput rep: K shard-local runs stepped through
/// `t_max` observed generations on either the batched or the scalar
/// path (no resampling — pure propagation, the quantity the batch layer
/// accelerates).
fn propagation_cell<M: SmcModel + Sync>(
    name: &str,
    model: &M,
    n: usize,
    t_max: usize,
    k: usize,
    batched: bool,
    ctx: &StepCtx,
) -> CellResult {
    run_cell(name, reps(), |_| {
        let per = n.div_ceil(k);
        let mut heaps: Vec<Heap> = (0..k).map(|_| Heap::new(CopyMode::LazySro)).collect();
        let mut runs: Vec<Vec<Lazy<M::State>>> = Vec::with_capacity(k);
        for (s, heap) in heaps.iter_mut().enumerate() {
            let (lo, hi) = ((s * per).min(n), ((s + 1) * per).min(n));
            runs.push(
                (lo..hi)
                    .map(|i| model.init(heap, &mut particle_rng(11, 0, i)))
                    .collect(),
            );
        }
        let mut acc = 0.0f64;
        for t in 1..=t_max {
            for s in 0..k {
                let base = (s * per).min(n);
                let winc = if batched {
                    model
                        .step_batched(&mut heaps[s], &mut runs[s], t, 11, true, base, ctx)
                        .expect("model must batch inference")
                } else {
                    model.step_population(&mut heaps[s], &mut runs[s], t, 11, true, base, ctx)
                };
                acc += winc.iter().sum::<f64>();
            }
        }
        std::hint::black_box(acc);
        for (heap, run) in heaps.iter_mut().zip(runs) {
            for h in run {
                heap.release(h);
            }
        }
        None
    })
}

/// Batched-numerics sweep (the SoA layer's acceptance benchmark): the
/// fused weight-reduction kernel vs the two-pass scalar sequence, and
/// `step_batched` propagation throughput vs the scalar `step_population`
/// reference per shard-local run at K ∈ {1, 2, 4} on LGSS and RBPF.
/// Every cell asserts bitwise identity between the paths first (the
/// `--batch` contract), so the numbers measure pure kernel effect.
/// Emits one JSON record per cell with a `speedup` field checked by
/// `tools/bench_check`.
fn bench_batch(backend: &Backend) {
    use lazycow::rng::Pcg64;
    use lazycow::stats::{ess, normalize_log_weights, weight_stats};
    println!("\n== Batched numeric path: SoA kernels vs scalar reference (JSON per cell) ==");
    let threads = backend.pool.n_threads();

    // -- weight-reduction: the fused single-pass normalize+ESS vs the
    //    two-pass sequence the filter trigger used before fusion. --
    let lanes = 1usize << 16;
    let mut rng = Pcg64::new(77);
    let lw: Vec<f64> = (0..lanes).map(|_| rng.gaussian(0.0, 3.0)).collect();
    let inner = 100usize;
    let mut w_ref = Vec::new();
    let mut scalar_out = (0.0f64, 0.0f64);
    let scalar_cell = run_cell("weight-reduction/scalar", reps(), |_| {
        for _ in 0..inner {
            let lmean = normalize_log_weights(&lw, &mut w_ref);
            scalar_out = (lmean, ess(&w_ref));
        }
        std::hint::black_box(&w_ref);
        None
    });
    let mut w_fused = Vec::new();
    let mut fused_out = (0.0f64, 0.0f64);
    let fused_cell = run_cell("weight-reduction/fused", reps(), |_| {
        for _ in 0..inner {
            fused_out = weight_stats(&lw, &mut w_fused);
        }
        std::hint::black_box(&w_fused);
        None
    });
    assert_eq!(scalar_out.0.to_bits(), fused_out.0.to_bits(), "fused lmean diverged");
    assert_eq!(scalar_out.1.to_bits(), fused_out.1.to_bits(), "fused ESS diverged");
    for (a, b) in w_ref.iter().zip(&w_fused) {
        assert_eq!(a.to_bits(), b.to_bits(), "fused weights diverged");
    }
    println!(
        "{{\"section\":\"batch\",\"cell\":\"weight-reduction\",\"lanes\":{},\"threads\":{},\"reps\":{},\"scalar_s\":{:.6},\"fused_s\":{:.6},\"speedup\":{:.4},\"bit_identical\":true}}",
        lanes,
        threads,
        scalar_cell.reps,
        scalar_cell.time_median,
        fused_cell.time_median,
        scalar_cell.time_median / fused_cell.time_median.max(1e-9),
    );

    // -- propagation throughput: batched vs scalar per shard-local run.
    //    The bitwise pre-flight runs on the CPU-oracle context; timing
    //    uses the backend context (compiled artifact when present). --
    let cpu_ctx = StepCtx {
        pool: &backend.pool,
        kalman: None,
        batch: true,
    };
    let t_list = 20usize;
    let list = ListModel::synthetic(t_list, DATA_SEED);
    assert_batched_matches_scalar(&list, 5, &cpu_ctx);
    let t_rbpf = 10usize;
    let rbpf = Rbpf::synthetic(t_rbpf, DATA_SEED);
    assert_batched_matches_scalar(&rbpf, 5, &cpu_ctx);
    let ctx = backend.ctx();
    for k in [1usize, 2, 4] {
        for (model_name, n, t) in [("list", 8192usize, t_list), ("rbpf", 1024usize, t_rbpf)] {
            let (scalar_cell, batched_cell) = if model_name == "list" {
                (
                    propagation_cell(&format!("list/K={k}/scalar"), &list, n, t, k, false, &ctx),
                    propagation_cell(&format!("list/K={k}/batched"), &list, n, t, k, true, &ctx),
                )
            } else {
                (
                    propagation_cell(&format!("rbpf/K={k}/scalar"), &rbpf, n, t, k, false, &ctx),
                    propagation_cell(&format!("rbpf/K={k}/batched"), &rbpf, n, t, k, true, &ctx),
                )
            };
            println!(
                "{{\"section\":\"batch\",\"cell\":\"propagation\",\"model\":\"{}\",\"shards\":{},\"threads\":{},\"particles\":{},\"steps\":{},\"reps\":{},\"scalar_s\":{:.6},\"batched_s\":{:.6},\"speedup\":{:.4},\"bit_identical\":true}}",
                model_name,
                k,
                threads,
                n,
                t,
                scalar_cell.reps,
                scalar_cell.time_median,
                batched_cell.time_median,
                scalar_cell.time_median / batched_cell.time_median.max(1e-9),
            );
        }
    }
}

/// Session-engine sweep (the resumable-coordinator acceptance
/// benchmark): (1) a bitwise identity pre-flight — a `FilterSession`
/// stepped generation by generation against the `run_filter_shards`
/// driver it now backs, on LGSS at K = 2; (2) per-generation step
/// latency through the session surface; (3) fork cost vs stepped
/// history depth — the platform claim: a fork is one lazy `deep_copy`
/// per particle, so its cost is flat in history while the ancestry heap
/// under it grows; (4) lazy fork vs eager whole-population copy on an
/// equivalent chain population. Emits one JSON record per cell;
/// `tools/bench_check` gates the identity bit, the fork-scaling ratio,
/// and the lazy-vs-eager speedup.
fn bench_session(backend: &Backend) {
    println!("\n== Session engine: identity, step latency, fork cost (JSON per cell) ==");
    let threads = backend.pool.n_threads();
    let ctx = backend.ctx();
    let n = 256usize;

    // -- identity + step latency: the driver *is* a session loop now;
    //    assert the bits anyway and measure the stepping overhead. --
    let t_id = 30usize;
    let model = ListModel::synthetic(t_id, DATA_SEED);
    let mut cfg = RunConfig::for_model(Model::List, Task::Inference, CopyMode::LazySro);
    cfg.n_particles = n;
    cfg.n_steps = t_id;
    cfg.shards = 2;
    cfg.seed = 20200401;
    let mut driver_bits = (0u64, 0u64);
    let driver_cell = run_cell("session/driver", reps(), |_| {
        let mut sh = ShardedHeap::new(cfg.mode, 2);
        let r = run_filter_shards(&model, &cfg, sh.shards_mut(), &ctx, Method::Bootstrap);
        driver_bits = (r.log_evidence.to_bits(), r.posterior_mean.to_bits());
        Some(r.global_peak_bytes as f64)
    });
    println!("  {}", driver_cell.pretty_row());
    let mut session_bits = (0u64, 0u64);
    let session_cell = run_cell("session/stepped", reps(), |_| {
        let mut sh = ShardedHeap::new(cfg.mode, 2);
        let mut s = FilterSession::begin(&model, &cfg, sh.shards_mut(), &ctx, Method::Bootstrap);
        for _ in 0..t_id {
            s.step(&model, sh.shards_mut(), &ctx);
        }
        let r = s.finish(&model, sh.shards_mut());
        session_bits = (r.log_evidence.to_bits(), r.posterior_mean.to_bits());
        Some(r.global_peak_bytes as f64)
    });
    println!("  {}", session_cell.pretty_row());
    assert_eq!(driver_bits, session_bits, "stepped session diverged from the driver");
    println!(
        "{{\"section\":\"session\",\"cell\":\"identity\",\"model\":\"list\",\"shards\":2,\"threads\":{},\"particles\":{},\"steps\":{},\"reps\":{},\"driver_s\":{:.6},\"session_s\":{:.6},\"speedup\":{:.4},\"bit_identical\":true}}",
        threads,
        n,
        t_id,
        session_cell.reps,
        driver_cell.time_median,
        session_cell.time_median,
        driver_cell.time_median / session_cell.time_median.max(1e-9),
    );
    println!(
        "{{\"section\":\"session\",\"cell\":\"step\",\"model\":\"list\",\"shards\":2,\"threads\":{},\"particles\":{},\"steps\":{},\"reps\":{},\"time_median_s\":{:.6},\"step_median_s\":{:.6}}}",
        threads,
        n,
        t_id,
        session_cell.reps,
        session_cell.time_median,
        session_cell.time_median / t_id as f64,
    );

    // -- fork cost vs history depth: one long-lived session, measured at
    //    increasing stepped depths. Only the forks are timed (the
    //    abandons — release + memo sweep — run between measurements).
    //    The live-object count shows the heap growing underneath while
    //    per-fork cost stays flat. --
    let t_horizon = 80usize;
    let fork_model = ListModel::synthetic(t_horizon, DATA_SEED);
    let mut fcfg = RunConfig::for_model(Model::List, Task::Inference, CopyMode::LazySro);
    fcfg.n_particles = n;
    fcfg.n_steps = t_horizon;
    fcfg.shards = 1;
    fcfg.seed = 20200401;
    fcfg.decommit_watermark = None;
    let forks_per_rep = 64usize;
    let mut sh = ShardedHeap::new(fcfg.mode, 1);
    let mut session = FilterSession::begin(&fork_model, &fcfg, sh.shards_mut(), &ctx, Method::Bootstrap);
    let mut depth = 0usize;
    let mut fork_medians: Vec<(usize, f64)> = Vec::new();
    for target in [5usize, 40, 80] {
        while depth < target {
            session.step(&fork_model, sh.shards_mut(), &ctx);
            depth += 1;
        }
        let mut times = Vec::with_capacity(reps().max(3));
        for _ in 0..reps().max(3) {
            let mut forks = Vec::with_capacity(forks_per_rep);
            let start = std::time::Instant::now();
            for _ in 0..forks_per_rep {
                forks.push(session.fork(sh.shards_mut()));
            }
            times.push(start.elapsed().as_secs_f64() / forks_per_rep as f64);
            for f in forks {
                f.abandon(sh.shards_mut());
            }
        }
        let (med, q1, q3) = median_iqr(&times);
        let live = sh.live_objects();
        println!(
            "  fork at depth {target:>3}: {:>9.1} ns/fork  ({} live objects under the population)",
            med * 1e9,
            live
        );
        println!(
            "{{\"section\":\"session\",\"cell\":\"fork\",\"model\":\"list\",\"shards\":1,\"particles\":{},\"depth\":{},\"forks_per_rep\":{},\"reps\":{},\"fork_s\":{:.9},\"fork_q1_s\":{:.9},\"fork_q3_s\":{:.9},\"live_objects\":{}}}",
            n,
            target,
            forks_per_rep,
            times.len(),
            med,
            q1,
            q3,
            live,
        );
        fork_medians.push((target, med));
    }
    session.abandon(sh.shards_mut());
    assert_eq!(sh.live_objects(), 0, "fork bench leaked");
    let (d_lo, lo) = fork_medians[0];
    let (d_hi, hi) = fork_medians[fork_medians.len() - 1];
    println!(
        "{{\"section\":\"session\",\"cell\":\"fork_scaling\",\"particles\":{},\"depth_lo\":{},\"depth_hi\":{},\"fork_lo_s\":{:.9},\"fork_hi_s\":{:.9},\"ratio\":{:.4}}}",
        n,
        d_lo,
        d_hi,
        lo,
        hi,
        hi / lo.max(1e-12),
    );

    // -- lazy fork vs eager whole-population copy, at the heap layer the
    //    fork reduces to: N chain roots of depth H, copied either by the
    //    O(1)-per-root lazy deep_copy or by the eager clone that walks
    //    every reachable node. --
    let h = 80usize;
    let mut heap = Heap::new(CopyMode::LazySro);
    let build = |heap: &mut Heap, len: usize, tag: i64| -> Lazy<Node> {
        let mut head = heap.alloc(Node {
            value: tag,
            next: Lazy::NULL,
        });
        for i in 1..len {
            let new = heap.alloc(Node {
                value: tag + i as i64,
                next: head,
            });
            heap.release(head);
            head = new;
        }
        head
    };
    let roots: Vec<Lazy<Node>> = (0..n).map(|i| build(&mut heap, h, i as i64)).collect();
    // Value pre-flight: both copy flavors must read back the same chain.
    {
        let chain_sum = |heap: &mut Heap, root: Lazy<Node>| -> i64 {
            let mut sum = 0i64;
            let mut cur = root;
            while !cur.is_null() {
                sum += heap.read(&mut cur, |nd| nd.value);
                cur = heap.read_ptr(&mut cur, |nd| nd.next);
            }
            sum
        };
        let lc = heap.deep_copy(&roots[0]);
        let ec = heap.deep_copy_eager(&roots[0]);
        let ls = chain_sum(&mut heap, lc);
        let es = chain_sum(&mut heap, ec);
        assert_eq!(ls, es, "lazy and eager copies read back differently");
        heap.release(lc);
        heap.release(ec);
        heap.sweep_memos();
    }
    let mut times_lazy = Vec::with_capacity(reps().max(3));
    let mut times_eager = Vec::with_capacity(reps().max(3));
    for _ in 0..reps().max(3) {
        let start = std::time::Instant::now();
        let copies: Vec<Lazy<Node>> = roots.iter().map(|r| heap.deep_copy(r)).collect();
        times_lazy.push(start.elapsed().as_secs_f64());
        for c in copies {
            heap.release(c);
        }
        heap.sweep_memos();
        let start = std::time::Instant::now();
        let copies: Vec<Lazy<Node>> = roots.iter().map(|r| heap.deep_copy_eager(r)).collect();
        times_eager.push(start.elapsed().as_secs_f64());
        for c in copies {
            heap.release(c);
        }
        heap.sweep_memos();
    }
    for r in roots {
        heap.release(r);
    }
    let (lm, _, _) = median_iqr(&times_lazy);
    let (em, _, _) = median_iqr(&times_eager);
    println!(
        "  population copy ({n} roots × {h} nodes): lazy {:.1} µs, eager {:.1} µs — x{:.1}",
        lm * 1e6,
        em * 1e6,
        em / lm.max(1e-12),
    );
    println!(
        "{{\"section\":\"session\",\"cell\":\"fork_vs_eager\",\"particles\":{},\"depth\":{},\"reps\":{},\"lazy_s\":{:.9},\"eager_s\":{:.9},\"speedup\":{:.4}}}",
        n,
        h,
        times_lazy.len(),
        lm,
        em,
        em / lm.max(1e-12),
    );
}

/// Observability overhead: the `--trace` span recorder must never change
/// what is computed and must cost roughly nothing when off. Runs LGSS
/// and PCFG at K = 4 with tracing off vs on (same seed), asserts the
/// results bitwise identical, and reports the wall-clock overhead ratio;
/// then times rendering a populated telemetry registry into the
/// Prometheus exposition text (the work a `/metrics` scrape amortizes).
/// `tools/bench_check` gates the identity bit and the overhead ratio.
fn bench_observability(backend: &Backend) {
    println!("\n== Observability: trace overhead + exposition render (JSON per cell) ==");
    let threads = backend.pool.n_threads();
    for model in [Model::List, Model::Pcfg] {
        let mut cfg = RunConfig::for_model(model, Task::Inference, CopyMode::LazySro);
        if paper_scale() {
            let (n, t_inf, _) = model.paper_scale();
            cfg.n_particles = n;
            cfg.n_steps = t_inf;
        }
        cfg.shards = 4;
        cfg.seed = 20200401;
        let trace_path = std::env::temp_dir().join(format!(
            "lazycow-bench-trace-{}-{}.jsonl",
            std::process::id(),
            model.name()
        ));
        let _ = std::fs::remove_file(&trace_path);
        let mut off_bits = (0u64, 0u64);
        let off_cell = run_cell(&format!("{}/trace-off", model.name()), reps(), |_| {
            let mut heap = ShardedHeap::new(cfg.mode, 4);
            let r = run_model(&cfg, &mut heap, &backend.ctx());
            off_bits = (r.log_evidence.to_bits(), r.posterior_mean.to_bits());
            Some(r.global_peak_bytes as f64)
        });
        println!("  {}", off_cell.pretty_row());
        let mut tcfg = cfg.clone();
        tcfg.trace = Some(trace_path.to_string_lossy().into_owned());
        let mut on_bits = (0u64, 0u64);
        let on_cell = run_cell(&format!("{}/trace-on", model.name()), reps(), |_| {
            let mut heap = ShardedHeap::new(tcfg.mode, 4);
            let r = run_model(&tcfg, &mut heap, &backend.ctx());
            on_bits = (r.log_evidence.to_bits(), r.posterior_mean.to_bits());
            Some(r.global_peak_bytes as f64)
        });
        println!("  {}", on_cell.pretty_row());
        assert_eq!(
            off_bits,
            on_bits,
            "tracing changed the {} output",
            model.name()
        );
        // O_APPEND across reps: total recorded lines, not per-run spans.
        let trace_lines = std::fs::read_to_string(&trace_path)
            .map(|s| s.lines().count())
            .unwrap_or(0);
        let _ = std::fs::remove_file(&trace_path);
        println!(
            "{{\"section\":\"observability\",\"cell\":\"trace\",\"model\":\"{}\",\"shards\":4,\"threads\":{},\"particles\":{},\"steps\":{},\"reps\":{},\"trace_off_s\":{:.6},\"trace_on_s\":{:.6},\"overhead_ratio\":{:.4},\"trace_lines\":{},\"bit_identical\":true}}",
            model.name(),
            threads,
            cfg.n_particles,
            cfg.n_steps,
            on_cell.reps,
            off_cell.time_median,
            on_cell.time_median,
            on_cell.time_median / off_cell.time_median.max(1e-9),
            trace_lines,
        );
    }

    // -- exposition render: step a session so every phase histogram and
    //    counter is populated, then time Registry::render alone. --
    let t_render = 20usize;
    let model = ListModel::synthetic(t_render, DATA_SEED);
    let mut cfg = RunConfig::for_model(Model::List, Task::Inference, CopyMode::LazySro);
    cfg.n_particles = 256;
    cfg.n_steps = t_render;
    cfg.shards = 2;
    cfg.seed = 20200401;
    let ctx = backend.ctx();
    let mut sh = ShardedHeap::new(cfg.mode, 2);
    let mut session = FilterSession::begin(&model, &cfg, sh.shards_mut(), &ctx, Method::Bootstrap);
    for _ in 0..t_render {
        session.step(&model, sh.shards_mut(), &ctx);
    }
    let mut times = Vec::with_capacity(reps().max(3));
    let mut series = 0usize;
    for _ in 0..reps().max(3) {
        let start = std::time::Instant::now();
        let text = session.telemetry().render();
        times.push(start.elapsed().as_secs_f64());
        series = text.lines().filter(|l| !l.starts_with('#')).count();
    }
    let _ = session.finish(&model, sh.shards_mut());
    let (med, q1, q3) = median_iqr(&times);
    println!(
        "  exposition render: {:>7.1} µs for {series} series (one stepped LGSS session)",
        med * 1e6
    );
    println!(
        "{{\"section\":\"observability\",\"cell\":\"render\",\"series\":{},\"reps\":{},\"render_s\":{:.9},\"render_q1_s\":{:.9},\"render_q3_s\":{:.9}}}",
        series,
        times.len(),
        med,
        q1,
        q3,
    );
}

/// Resampler ablation: the constant c in the t + cN·logN reachable-set
/// bound depends on offspring variance — systematic < stratified <
/// multinomial (Jacob et al. 2015's discussion).
fn bench_resamplers() {
    use lazycow::rng::Pcg64;
    use lazycow::smc::resample::{multinomial, offspring_counts, stratified, systematic};
    println!("\n== Resampler ablation: offspring variance drives ancestry width ==");
    let n = 1024;
    let mut rng = Pcg64::new(42);
    // Moderately skewed weights.
    let w: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
    for (name, f) in [
        ("multinomial", multinomial as fn(&mut Pcg64, &[f64], usize) -> Vec<usize>),
        ("stratified", stratified),
        ("systematic", systematic),
    ] {
        let mut zero = 0usize;
        let mut var = 0.0;
        let reps = 200;
        for _ in 0..reps {
            let anc = f(&mut rng, &w, n);
            let counts = offspring_counts(&anc, n);
            zero += counts.iter().filter(|c| **c == 0).count();
            let mean = 1.0;
            var += counts
                .iter()
                .map(|c| (*c as f64 - mean).powi(2))
                .sum::<f64>()
                / n as f64;
        }
        println!(
            "  {:<12} offspring var {:.3}  extinct parents/gen {:.1}%",
            name,
            var / reps as f64,
            100.0 * zero as f64 / (reps * n) as f64
        );
    }
    println!("  (lower variance -> fewer extinct lineages -> wider shared ancestry)");
}

fn main() {
    let secs = sections();
    let backend = Backend::new();
    println!(
        "lazycow paper benchmarks — scale={}, reps={}",
        if paper_scale() { "paper" } else { "default" },
        reps()
    );
    for s in &secs {
        match s.as_str() {
            "fig5" => bench_fig5(&backend),
            "fig6" => bench_fig6(&backend),
            "fig7" => bench_fig7(&backend),
            "ablation" => bench_ablation(&backend),
            "treebound" => bench_treebound(),
            "micro" => bench_micro(),
            "functional" => bench_functional(),
            "resamplers" => bench_resamplers(),
            "shards" => bench_shards(&backend),
            "rebalance" => bench_rebalance(&backend),
            "alloc" => {
                bench_alloc(&backend);
                bench_alloc_churn();
            }
            "batch" => bench_batch(&backend),
            "session" => bench_session(&backend),
            "observability" => bench_observability(&backend),
            "heapev" => {
                bench_heapev_trim();
                bench_heapev_evacuate();
            }
            other => eprintln!("unknown section {other}"),
        }
    }
    println!("\nbench complete.");
}
