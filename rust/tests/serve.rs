//! The serve subsystem's contracts, end to end:
//!
//! - **Hostile input**: blank lines, comments, unknown verbs, malformed
//!   observations, arity errors, double `finish`, commands on closed
//!   sessions — every one is an `err ...` reply, never a panic, and the
//!   engine stays consistent and usable afterwards.
//! - **Streaming = batch, per model**: every model built observation-by-
//!   observation through `stream_observation` (round-tripped through the
//!   protocol's string tokens) finishes bit-identical to the batch run
//!   over the same synthetic data, at K = 1 and K = 3.
//! - **Interleaving is invisible**: sessions multiplexed over one shared
//!   sharded heap — through the protocol surface and over real TCP with
//!   concurrent clients — reply byte-identically to the same scripts run
//!   solo, and per-session telemetry attribution stays exact.
//! - **Observability**: `render_metrics` aggregates per-session
//!   registries under `{session,model}` labels and per-shard residency
//!   under `{shard}`; the `/metrics` HTTP responder serves it with
//!   serve-level counters; the `wall=` reply token stays stable and
//!   final; request/error labels are bounded.

use lazycow::config::{Model, RunConfig, Task};
use lazycow::heap::{CopyMode, ShardedHeap};
use lazycow::models::{Crbd, ListModel, Mot, Pcfg, Rbpf, Vbd, DATA_SEED};
use lazycow::pool::ThreadPool;
use lazycow::serve::{serve_method, serve_on, MetricsHub, ServeEngine, Verdict};
use lazycow::smc::{run_filter_shards, FilterSession, Method, RebalancePolicy, SmcModel, StepCtx};
use lazycow::telemetry;

fn ctx(pool: &ThreadPool) -> StepCtx<'_> {
    StepCtx { pool, kalman: None, batch: true }
}

/// A serve template over K = 2 shards (pinned, so tests don't depend on
/// the host's core count).
fn template() -> RunConfig {
    let mut cfg = RunConfig::for_model(Model::List, Task::Inference, CopyMode::LazySro);
    cfg.shards = 2;
    cfg
}

fn engine() -> ServeEngine {
    ServeEngine::new(template(), ThreadPool::new(2), None)
}

/// Execute one line, expecting reply lines; returns them.
fn reply(e: &mut ServeEngine, line: &str) -> Vec<String> {
    match e.execute(line) {
        Verdict::Reply(r) | Verdict::Drain(r) => r,
        Verdict::Silent => panic!("expected a reply to {line:?}, got silence"),
    }
}

fn expect_ok(e: &mut ServeEngine, line: &str) -> String {
    let r = reply(e, line);
    let last = r.last().expect("non-empty reply").clone();
    assert!(last.starts_with("ok "), "expected ok for {line:?}, got {last:?}");
    last
}

fn expect_err(e: &mut ServeEngine, line: &str) {
    let r = reply(e, line);
    assert_eq!(r.len(), 1, "error replies are single lines: {r:?}");
    assert!(r[0].starts_with("err "), "expected err for {line:?}, got {:?}", r[0]);
}

/// Run a whole script, collecting every reply line; stops after a drain.
fn run_script(e: &mut ServeEngine, script: &[String]) -> Vec<String> {
    let mut out = Vec::new();
    for line in script {
        match e.execute(line) {
            Verdict::Silent => {}
            Verdict::Reply(r) => out.extend(r),
            Verdict::Drain(r) => {
                out.extend(r);
                break;
            }
        }
    }
    out
}

/// Drop the ` wall=...` field (the one nondeterministic reply token —
/// always the final token of its line, see `serve::fmt_wall`).
fn strip_wall(line: &str) -> String {
    match line.find(" wall=") {
        Some(i) => line[..i].to_string(),
        None => line.to_string(),
    }
}

fn strip_walls(lines: &[String]) -> Vec<String> {
    lines.iter().map(|l| strip_wall(l)).collect()
}

// ---------------------------------------------------------------------
// Hostile input (the protocol never kills the process).
// ---------------------------------------------------------------------

#[test]
fn hostile_input_yields_errors_not_death() {
    let mut e = engine();

    // Blank lines and comments are silently skipped.
    assert!(matches!(e.execute(""), Verdict::Silent));
    assert!(matches!(e.execute("   \t "), Verdict::Silent));
    assert!(matches!(e.execute("# a comment"), Verdict::Silent));

    // Unknown verbs and malformed commands are protocol errors.
    expect_err(&mut e, "bogus");
    expect_err(&mut e, "obs");
    expect_err(&mut e, "obs nosession 1.0");
    expect_err(&mut e, "open");
    expect_err(&mut e, "open a");
    expect_err(&mut e, "open a nomodel");
    expect_err(&mut e, "open a list particles=abc");
    expect_err(&mut e, "open a list particles=0");
    expect_err(&mut e, "open a list frobnicate=1");
    expect_err(&mut e, "open a list particles");
    assert_eq!(e.session_count(), 0, "failed opens must open nothing");

    // A healthy session, then malformed observations against it.
    expect_ok(&mut e, "open a list particles=16 seed=7");
    expect_err(&mut e, "open a list"); // duplicate name
    expect_err(&mut e, "obs a abc"); // non-numeric
    expect_err(&mut e, "obs a inf"); // non-finite
    expect_err(&mut e, "obs a 1.0 2.0"); // wrong arity for list
    let r = expect_ok(&mut e, "obs a 0.5");
    assert!(r.contains(" t=1 "), "first accepted obs steps generation 1: {r}");
    expect_err(&mut e, "whatif a"); // no observation groups
    expect_err(&mut e, "whatif a oops"); // bad token
    expect_ok(&mut e, "whatif a 0.1; -0.2");
    // The failed lines above left the session consistent: the next
    // accepted observation is generation 2, not something corrupted.
    let r = expect_ok(&mut e, "obs a -0.25");
    assert!(r.contains(" t=2 "), "session state survived the errors: {r}");
    let t = expect_ok(&mut e, "telemetry a");
    assert_eq!(t, "ok telemetry a");

    // Fork arity and name collisions.
    expect_err(&mut e, "fork a");
    expect_err(&mut e, "fork a b c");
    expect_ok(&mut e, "fork a b");
    expect_err(&mut e, "fork a b"); // target exists
    expect_err(&mut e, "fork ghost c"); // source missing

    // Double finish / commands on a closed session.
    expect_ok(&mut e, "finish b");
    expect_err(&mut e, "finish b");
    expect_err(&mut e, "obs b 1.0");
    expect_err(&mut e, "telemetry b");
    expect_ok(&mut e, "close a");
    expect_err(&mut e, "close a");
    assert_eq!(e.session_count(), 0);
    assert_eq!(e.live_objects(), 0, "finish/close released every object");

    // The engine is still fully usable.
    expect_ok(&mut e, "open z vbd particles=8 seed=3");
    expect_ok(&mut e, "obs z 4");
    expect_err(&mut e, "obs z -1"); // negative case count
    let drain = reply(&mut e, "finish-all");
    assert!(drain.iter().any(|l| l.starts_with("ok finish z ")));
    assert_eq!(drain.last().unwrap(), "ok finish-all sessions=1");
    assert_eq!(e.live_objects(), 0);
}

#[test]
fn finish_with_zero_steps_reports_instead_of_panicking() {
    let mut e = engine();
    expect_ok(&mut e, "open a list particles=8 seed=1");
    let r = expect_ok(&mut e, "finish a");
    assert!(r.contains(" steps=0 "), "zero-generation finish is legal: {r}");
    assert_eq!(e.live_objects(), 0);
}

// ---------------------------------------------------------------------
// Streaming construction ≡ batch, for every model, at K = 1 and K = 3.
// ---------------------------------------------------------------------

/// Feed `streaming` one protocol-token group per generation, stepping a
/// session each time; the finish must be bit-identical to the batch run
/// over `synth` (which holds the same observations, built eagerly).
fn stream_vs_batch<M>(
    cfg: &RunConfig,
    synth: &M,
    mut streaming: M,
    tokens: &[Vec<String>],
    k: usize,
) where
    M: SmcModel + Sync,
{
    let pool = ThreadPool::new(3);
    let ctx = ctx(&pool);
    let method = serve_method(cfg.model);

    let mut oracle = ShardedHeap::new(cfg.mode, k);
    let full = run_filter_shards(synth, cfg, oracle.shards_mut(), &ctx, method);

    let mut heap = ShardedHeap::new(cfg.mode, k);
    let mut session = FilterSession::begin(&streaming, cfg, heap.shards_mut(), &ctx, method);
    for group in tokens {
        let toks: Vec<&str> = group.iter().map(String::as_str).collect();
        streaming
            .stream_observation(&toks)
            .unwrap_or_else(|e| panic!("{} rejected its own tokens: {e}", synth.name()));
        session.step(&streaming, heap.shards_mut(), &ctx);
    }
    let r = session.finish(&streaming, heap.shards_mut());
    assert_eq!(
        r.log_evidence.to_bits(),
        full.log_evidence.to_bits(),
        "{} K={k}: streamed vs batch evidence",
        synth.name()
    );
    assert_eq!(
        r.posterior_mean.to_bits(),
        full.posterior_mean.to_bits(),
        "{} K={k}: streamed vs batch posterior",
        synth.name()
    );
    assert_eq!(heap.live_objects(), 0, "{} K={k}: leaked", synth.name());
}

fn small_cfg(model: Model, t: usize) -> RunConfig {
    let mut cfg = RunConfig::for_model(model, Task::Inference, CopyMode::LazySro);
    cfg.n_particles = 24;
    cfg.n_steps = t;
    cfg.seed = 77;
    cfg.shards = 0;
    cfg
}

#[test]
fn every_model_streams_bit_identically_to_batch() {
    let t = 10;
    for k in [1usize, 3] {
        let m = ListModel::synthetic(t, DATA_SEED);
        let tokens: Vec<Vec<String>> = m.obs.iter().map(|y| vec![y.to_string()]).collect();
        stream_vs_batch(&small_cfg(Model::List, t), &m, ListModel::streaming(), &tokens, k);

        let m = Rbpf::synthetic(t, DATA_SEED);
        let tokens: Vec<Vec<String>> = m
            .obs
            .iter()
            .map(|(y1, y2)| vec![y1.to_string(), y2.to_string()])
            .collect();
        stream_vs_batch(&small_cfg(Model::Rbpf, t), &m, Rbpf::streaming(), &tokens, k);

        let m = Pcfg::synthetic(t, DATA_SEED);
        let tokens: Vec<Vec<String>> = m.obs.iter().map(|y| vec![y.to_string()]).collect();
        stream_vs_batch(&small_cfg(Model::Pcfg, t), &m, Pcfg::streaming(), &tokens, k);

        let m = Vbd::synthetic(t, DATA_SEED);
        let tokens: Vec<Vec<String>> = m.obs.iter().map(|y| vec![y.to_string()]).collect();
        stream_vs_batch(&small_cfg(Model::Vbd, t), &m, Vbd::streaming(), &tokens, k);

        let m = Mot::synthetic(t, DATA_SEED);
        let tokens: Vec<Vec<String>> = m
            .obs
            .iter()
            .map(|scan| scan.iter().map(|(x, y)| format!("{x},{y}")).collect())
            .collect();
        stream_vs_batch(&small_cfg(Model::Mot, t), &m, Mot::streaming(), &tokens, k);

        let m = Crbd::synthetic(t + 1, DATA_SEED); // tips → t events
        let tokens: Vec<Vec<String>> = m
            .events
            .iter()
            .map(|e| vec![e.dt.to_string(), e.lineages.to_string(), e.remaining.to_string()])
            .collect();
        stream_vs_batch(&small_cfg(Model::Crbd, t), &m, Crbd::streaming(), &tokens, k);
    }
}

// ---------------------------------------------------------------------
// Interleaving sessions over one shared heap is invisible in replies.
// ---------------------------------------------------------------------

fn list_script(name: &str, t: usize) -> Vec<String> {
    let data = ListModel::synthetic(t + 1, DATA_SEED);
    let mut s = vec![format!("open {name} list particles=32 seed=5")];
    for y in &data.obs[..t] {
        s.push(format!("obs {name} {y}"));
    }
    s.push(format!("whatif {name} {}", data.obs[t]));
    s.push(format!("finish {name}"));
    s
}

fn vbd_script(name: &str, t: usize) -> Vec<String> {
    let data = Vbd::synthetic(t, DATA_SEED);
    let mut s = vec![format!("open {name} vbd particles=24 seed=9")];
    for y in &data.obs {
        s.push(format!("obs {name} {y}"));
    }
    s.push(format!("finish {name}"));
    s
}

/// Reply lines belonging to a session (`ok <verb> <name> ...`).
fn for_session(lines: &[String], name: &str) -> Vec<String> {
    lines
        .iter()
        .filter(|l| l.split_whitespace().nth(2) == Some(name))
        .map(|l| strip_wall(l))
        .collect()
}

#[test]
fn interleaved_sessions_reply_identically_to_solo_runs() {
    let t = 8;
    let script_a = list_script("a", t);
    let script_b = vbd_script("b", t);

    let solo_a = run_script(&mut engine(), &script_a);
    let solo_b = run_script(&mut engine(), &script_b);
    assert!(solo_a.iter().all(|l| l.starts_with("ok ")), "{solo_a:?}");
    assert!(solo_b.iter().all(|l| l.starts_with("ok ")), "{solo_b:?}");

    // Interleave the two scripts line by line on one shared heap.
    let mut mixed = Vec::new();
    let (mut ia, mut ib) = (script_a.iter(), script_b.iter());
    loop {
        let (a, b) = (ia.next(), ib.next());
        mixed.extend(a.cloned());
        mixed.extend(b.cloned());
        if a.is_none() && b.is_none() {
            break;
        }
    }
    let mut shared = engine();
    let got = run_script(&mut shared, &mixed);
    assert_eq!(for_session(&got, "a"), strip_walls(&solo_a));
    assert_eq!(for_session(&got, "b"), strip_walls(&solo_b));
    assert_eq!(shared.live_objects(), 0);
}

#[test]
fn whatif_and_fork_leave_the_live_session_untouched() {
    let t = 6;
    let data = ListModel::synthetic(t, DATA_SEED);

    // Plain run: open + t observations + finish.
    let mut plain = vec!["open a list particles=32 seed=5".to_string()];
    for y in &data.obs {
        plain.push(format!("obs a {y}"));
    }
    plain.push("finish a".to_string());
    let baseline = run_script(&mut engine(), &plain);

    // Same run with speculative traffic injected after every
    // observation: a what-if and a fork (stepped separately, then
    // closed). The `a`-session replies must be byte-identical.
    let mut noisy = vec!["open a list particles=32 seed=5".to_string()];
    for (i, y) in data.obs.iter().enumerate() {
        noisy.push(format!("obs a {y}"));
        noisy.push(format!("whatif a {}; {}", 0.25 * (i as f64 + 1.0), -0.5));
        noisy.push(format!("fork a spec{i}"));
        noisy.push(format!("obs spec{i} {}", 1.5 * (i as f64 - 2.0)));
        noisy.push(format!("close spec{i}"));
    }
    noisy.push("finish a".to_string());
    let mut e = engine();
    let got = run_script(&mut e, &noisy);
    assert_eq!(for_session(&got, "a"), strip_walls(&baseline));
    assert_eq!(e.live_objects(), 0);
}

// ---------------------------------------------------------------------
// Telemetry attribution stays exact when sessions share shards.
// ---------------------------------------------------------------------

#[test]
fn interleaved_sessions_attribute_telemetry_exactly() {
    // Deterministic-counter configuration: no rebalancer, no stealing
    // (steal and greedy-migration counts vary run to run by design).
    let t_max = 10;
    let counters = [
        telemetry::SESSION_STEPS_TOTAL,
        telemetry::SESSION_RESAMPLES_TOTAL,
        telemetry::SESSION_ATTEMPTS_TOTAL,
        telemetry::TRANSPLANTS_TOTAL,
        telemetry::LAZY_COPIES_TOTAL,
        telemetry::EAGER_COPIES_TOTAL,
    ];
    for k in [1usize, 2] {
        let model_a = ListModel::synthetic(t_max, 21);
        let model_b = ListModel::synthetic(t_max, 22);
        let pool = ThreadPool::new(2);
        let ctx = ctx(&pool);
        let mut cfg_a = RunConfig::for_model(Model::List, Task::Inference, CopyMode::LazySro);
        cfg_a.n_particles = 48;
        cfg_a.n_steps = t_max;
        cfg_a.seed = 31;
        cfg_a.rebalance = RebalancePolicy::Off;
        cfg_a.steal = false;
        let mut cfg_b = cfg_a.clone();
        cfg_b.n_particles = 32;
        cfg_b.seed = 32;

        // Solo reference: each session alone on a private heap.
        let solo = |cfg: &RunConfig, model: &ListModel| {
            let mut heap = ShardedHeap::new(CopyMode::LazySro, k);
            let mut s =
                FilterSession::begin(model, cfg, heap.shards_mut(), &ctx, Method::Bootstrap);
            for _ in 0..t_max {
                s.step(model, heap.shards_mut(), &ctx);
            }
            let c: Vec<u64> = counters.iter().map(|n| s.telemetry().counter(n)).collect();
            let r = s.finish(model, heap.shards_mut());
            (c, r)
        };
        let (ca_solo, ra_solo) = solo(&cfg_a, &model_a);
        let (cb_solo, rb_solo) = solo(&cfg_b, &model_b);

        // Interleaved: both sessions alternate steps on one shard set.
        let mut heap = ShardedHeap::new(CopyMode::LazySro, k);
        let base = heap.metrics();
        let mut sa =
            FilterSession::begin(&model_a, &cfg_a, heap.shards_mut(), &ctx, Method::Bootstrap);
        let mut sb =
            FilterSession::begin(&model_b, &cfg_b, heap.shards_mut(), &ctx, Method::Bootstrap);
        for _ in 0..t_max {
            sa.step(&model_a, heap.shards_mut(), &ctx);
            sb.step(&model_b, heap.shards_mut(), &ctx);
        }
        let ca: Vec<u64> = counters.iter().map(|n| sa.telemetry().counter(n)).collect();
        let cb: Vec<u64> = counters.iter().map(|n| sb.telemetry().counter(n)).collect();
        assert_eq!(ca, ca_solo, "K={k}: session a counters drift under interleaving");
        assert_eq!(cb, cb_solo, "K={k}: session b counters drift under interleaving");

        // The per-session splits sum to the shared shards' own totals:
        // nothing double-charged, nothing dropped.
        let agg = heap.metrics();
        let tele = |s: &FilterSession<_>, n: &'static str| s.telemetry().counter(n);
        assert_eq!(
            tele(&sa, telemetry::TRANSPLANTS_TOTAL) + tele(&sb, telemetry::TRANSPLANTS_TOTAL),
            (agg.transplants - base.transplants) as u64,
            "K={k}: transplant split"
        );
        assert_eq!(
            tele(&sa, telemetry::LAZY_COPIES_TOTAL) + tele(&sb, telemetry::LAZY_COPIES_TOTAL),
            (agg.lazy_copies - base.lazy_copies) as u64,
            "K={k}: lazy-copy split"
        );
        assert_eq!(
            tele(&sa, telemetry::EAGER_COPIES_TOTAL) + tele(&sb, telemetry::EAGER_COPIES_TOTAL),
            (agg.eager_copies - base.eager_copies) as u64,
            "K={k}: eager-copy split"
        );

        // And interleaving never reaches the outputs.
        let ra = sa.finish(&model_a, heap.shards_mut());
        let rb = sb.finish(&model_b, heap.shards_mut());
        assert_eq!(ra.log_evidence.to_bits(), ra_solo.log_evidence.to_bits());
        assert_eq!(rb.log_evidence.to_bits(), rb_solo.log_evidence.to_bits());
        assert_eq!(heap.live_objects(), 0);
    }
}

// ---------------------------------------------------------------------
// The TCP front-end: concurrent clients, one shared heap, clean drain.
// ---------------------------------------------------------------------

#[test]
fn tcp_concurrent_clients_match_solo_replies_and_drain_cleanly() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::{TcpListener, TcpStream};
    use std::time::Duration;

    let t = 8;
    let script_a = list_script("a", t);
    let script_b = vbd_script("b", t);
    let solo_a = run_script(&mut engine(), &script_a);
    let solo_b = run_script(&mut engine(), &script_b);

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind an OS-assigned port");
    let addr = listener.local_addr().unwrap();
    let hub = MetricsHub::new();
    let server_hub = std::sync::Arc::clone(&hub);
    let server = std::thread::spawn(move || serve_on(engine(), listener, server_hub));

    let connect = move || -> (TcpStream, BufReader<TcpStream>) {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut banner = String::new();
        reader.read_line(&mut banner).expect("banner");
        assert!(banner.starts_with("# lazycow serve"), "{banner:?}");
        (stream, reader)
    };

    // Two concurrent clients, one session each, interleaving at whatever
    // pace the scheduler gives them.
    let client = |script: Vec<String>| {
        std::thread::spawn(move || -> Vec<String> {
            let (mut w, mut r) = connect();
            let mut replies = Vec::new();
            for line in script {
                writeln!(w, "{line}").expect("send");
                let mut reply = String::new();
                r.read_line(&mut reply).expect("reply");
                replies.push(reply.trim_end().to_string());
            }
            replies
        })
    };
    let ha = client(script_a);
    let hb = client(script_b);
    let got_a = ha.join().expect("client a");
    let got_b = hb.join().expect("client b");
    assert!(got_a.iter().all(|l| l.starts_with("ok ")), "{got_a:?}");
    assert!(got_b.iter().all(|l| l.starts_with("ok ")), "{got_b:?}");
    assert_eq!(strip_walls(&got_a), strip_walls(&solo_a));
    assert_eq!(strip_walls(&got_b), strip_walls(&solo_b));

    // EOF mid-command: a partial line with no newline, then hang up.
    // The fragment must be dropped, not executed.
    {
        let (mut w, _r) = connect();
        w.write_all(b"open ghost list").expect("partial write");
    }

    // Drain: both sessions were already finished by their clients, so
    // finish-all reports zero remaining — proving the ghost fragment
    // never opened a session — and the server exits cleanly.
    let (mut w, mut r) = connect();
    writeln!(w, "finish-all").expect("send finish-all");
    let last = loop {
        let mut line = String::new();
        r.read_line(&mut line).expect("drain reply");
        let line = line.trim_end().to_string();
        if line.starts_with("ok finish-all") {
            break line;
        }
    };
    assert_eq!(last, "ok finish-all sessions=0");
    server.join().expect("server thread").expect("serve_on result");

    // The hub observed the traffic: connections counted, requests
    // labeled by verb, and the draining gauge flipped on drain.
    let text = hub.scrape();
    assert!(text.contains("serve_connections_total 4"), "{text}");
    assert!(text.contains("serve_requests_total{verb=\"obs\"}"), "{text}");
    assert!(text.contains("serve_requests_total{verb=\"finish-all\"} 1"), "{text}");
    assert!(text.contains("serve_draining 1"), "{text}");
}

// ---------------------------------------------------------------------
// Observability: /metrics aggregation, the HTTP responder, the wall
// token, and bounded request/error labels.
// ---------------------------------------------------------------------

#[test]
fn metrics_render_merges_sessions_and_shards_with_labels() {
    let mut e = engine();
    expect_ok(&mut e, "open alpha list particles=16 seed=7");
    expect_ok(&mut e, "open beta vbd particles=8 seed=3");
    expect_ok(&mut e, "obs alpha 0.5");
    expect_ok(&mut e, "obs beta 4");
    let text = e.render_metrics();

    // Per-session series under {session,model} labels.
    assert!(
        text.contains("session_steps_total{session=\"alpha\",model=\"list\"} 1"),
        "{text}"
    );
    assert!(
        text.contains("session_steps_total{session=\"beta\",model=\"vbd\"} 1"),
        "{text}"
    );
    // Per-phase wall histograms keep their phase label and gain the
    // session labels.
    assert!(
        text.contains("phase_wall_seconds_count{phase=\"propagate\",session=\"alpha\",model=\"list\"}"),
        "{text}"
    );
    // Per-shard residency gauges for every shard of the K=2 heap.
    assert!(text.contains("shard_live_bytes{shard=\"0\"}"), "{text}");
    assert!(text.contains("shard_live_bytes{shard=\"1\"}"), "{text}");
    assert!(text.contains("shard_live_objects{shard=\"0\"}"), "{text}");
    assert!(text.contains("shard_committed_bytes{shard=\"1\"}"), "{text}");
    // Spec shape: exactly one HELP/TYPE header per family.
    assert_eq!(text.matches("# TYPE session_steps_total counter").count(), 1);
    assert_eq!(text.matches("# HELP shard_live_bytes").count(), 1);
    // Deterministic: the same engine state renders byte-identically.
    assert_eq!(text, e.render_metrics());

    // Finished sessions drop out of the next render; shard gauges stay.
    reply(&mut e, "finish-all");
    let after = e.render_metrics();
    assert!(!after.contains("session=\"alpha\""), "{after}");
    assert!(after.contains("shard_live_bytes{shard=\"0\"}"), "{after}");
}

#[test]
fn metrics_http_answers_scrapes_and_rejects_other_requests() {
    use lazycow::serve::{error_reason, serve_metrics_on, verb_label};
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};

    let hub = MetricsHub::new();
    hub.note_connection();
    hub.note_request(verb_label("obs a 0.5"), 0.002, None);
    hub.note_request(
        verb_label("frobnicate x"),
        0.001,
        error_reason("err unknown command 'frobnicate' (open|obs)"),
    );
    hub.set_engine_snapshot(engine().render_metrics());

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind metrics port");
    let addr = listener.local_addr().unwrap();
    let responder = serve_metrics_on(std::sync::Arc::clone(&hub), listener).expect("responder");

    let roundtrip = |request: &str| -> String {
        let mut s = TcpStream::connect(addr).expect("connect scrape");
        s.write_all(request.as_bytes()).expect("send request");
        let mut out = String::new();
        s.read_to_string(&mut out).expect("read response");
        out
    };
    let ok = roundtrip("GET /metrics HTTP/1.1\r\nHost: t\r\nAccept: */*\r\n\r\n");
    assert!(ok.starts_with("HTTP/1.1 200 OK\r\n"), "{ok}");
    assert!(ok.contains("Content-Type: text/plain; version=0.0.4"), "{ok}");
    assert!(ok.contains("serve_connections_total 1"), "{ok}");
    assert!(ok.contains("serve_requests_total{verb=\"obs\"} 1"), "{ok}");
    assert!(ok.contains("serve_requests_total{verb=\"other\"} 1"), "{ok}");
    assert!(ok.contains("serve_errors_total{reason=\"unknown-verb\"} 1"), "{ok}");
    assert!(ok.contains("serve_request_seconds_count 2"), "{ok}");
    assert!(ok.contains("serve_draining 0"), "{ok}");
    // The engine snapshot rides along in the same exposition.
    assert!(ok.contains("shard_live_bytes{shard=\"0\"}"), "{ok}");

    let not_found = roundtrip("GET /other HTTP/1.1\r\n\r\n");
    assert!(not_found.starts_with("HTTP/1.1 404 "), "{not_found}");
    let bad_method = roundtrip("POST /metrics HTTP/1.1\r\n\r\n");
    assert!(bad_method.starts_with("HTTP/1.1 405 "), "{bad_method}");

    hub.shutdown();
    responder.join().expect("responder joins");
}

#[test]
fn wall_token_is_stable_and_final() {
    use lazycow::serve::fmt_wall;
    assert_eq!(fmt_wall(0.1234567), "wall=0.123");
    assert_eq!(fmt_wall(0.0), "wall=0.000");
    let mut e = engine();
    expect_ok(&mut e, "open a list particles=8 seed=1");
    expect_ok(&mut e, "obs a 0.5");
    let r = expect_ok(&mut e, "finish a");
    let last = r.split_whitespace().last().unwrap();
    let val = last.strip_prefix("wall=").expect("wall= is the final token");
    val.parse::<f64>().expect("bare seconds, no unit suffix");
}

#[test]
fn request_and_error_labels_are_bounded() {
    use lazycow::serve::{error_reason, verb_label};
    assert_eq!(verb_label("obs a 0.5"), "obs");
    assert_eq!(verb_label("  open a list"), "open");
    assert_eq!(verb_label("finish-all"), "finish-all");
    assert_eq!(verb_label(""), "comment");
    assert_eq!(verb_label("  # note"), "comment");
    assert_eq!(verb_label("frobnicate x y"), "other");
    assert_eq!(error_reason("ok obs a t=1"), None);
    assert_eq!(error_reason("err unknown command 'x' (...)"), Some("unknown-verb"));
    assert_eq!(error_reason("err no open session 'a'"), Some("no-session"));
    assert_eq!(error_reason("err session 'a' already open"), Some("name-taken"));
    assert_eq!(error_reason("err usage: obs <name> <tokens...>"), Some("usage"));
    assert_eq!(error_reason("err server draining"), Some("draining"));
    assert_eq!(error_reason("err particles must be >= 1"), Some("bad-input"));
}
