//! Differential test harness: the engine's determinism contract, pinned.
//!
//! [`assert_bitwise_equiv`] is a reusable runner that sweeps the full
//! scheduling matrix — K ∈ {1, 2, 4} × rebalance policy × steal on/off ×
//! copy mode, plus the payload-allocator axis (`system` vs the default
//! `slab`), the decommit axis (watermark off / 0 / the default keep-2),
//! the batched-numerics axis (`--batch off`, forcing the scalar
//! per-particle reference path), the evacuation axis
//! (`--evacuate-threshold` 0 / 0.5 — opportunistic defrag relocates
//! storage and may never change one output bit), and the tracing axis
//! (`--trace` on vs off — spans are pure measurement and may never
//! reach the output) —
//! against the K = 1 / steal-off / policy-off oracle and
//! demands *bitwise* equality of `log_evidence` and `posterior_mean`
//! (plus equal attempt counts, zero leaks, per-shard alloc/free balance,
//! slab- and raw-gauge consistency, decommit accounting, and the
//! global-peak ≤ sum-of-peaks invariant) in every cell. It replaces the
//! ad-hoc matrix that used to live in `tests/sharded.rs`.
//!
//! Three workloads cover every propagation path: LGSS (bootstrap, the
//! exact-Kalman oracle model), PCFG (auxiliary PF with lookahead
//! resampling and heavy-tailed derivation stacks), and CRBD (alive PF
//! under the per-slot retry-stream contract v2).

use lazycow::config::{Model, RunConfig, Task};
use lazycow::heap::{AllocatorKind, CopyMode, ShardedHeap, CHUNK_BYTES};
use lazycow::models::{Crbd, ListModel, Pcfg};
use lazycow::pool::ThreadPool;
use lazycow::smc::{
    run_filter_shards, FilterSession, Method, RebalancePolicy, SmcModel, StepCtx,
};

fn ctx(pool: &ThreadPool) -> StepCtx<'_> {
    StepCtx { pool, kalman: None, batch: true }
}

/// One matrix cell's identity-relevant output.
#[derive(Clone, Copy, PartialEq, Debug)]
struct Fingerprint {
    log_evidence: u64,
    posterior_mean: u64,
    attempts: usize,
}

fn run_cell<M: SmcModel + Sync>(
    model: &M,
    cfg: &RunConfig,
    method: Method,
    pool: &ThreadPool,
    k: usize,
    label: &str,
) -> Fingerprint {
    let mut sh = ShardedHeap::with_allocator(cfg.mode, k, cfg.allocator);
    let r = run_filter_shards(model, cfg, sh.shards_mut(), &ctx(pool), method);
    // Structural invariants hold in every cell, not just the oracle.
    assert_eq!(sh.live_objects(), 0, "{label}: leaked live objects");
    for (s, h) in sh.shards().iter().enumerate() {
        let m = &h.metrics;
        assert_eq!(
            m.total_allocs,
            m.total_frees + m.live_objects,
            "{label}: shard {s} alloc/free/live balance broken"
        );
        // Slab-gauge consistency: every payload allocation takes exactly
        // one source, freed blocks stop counting as live, and committed
        // bytes track the chunk count.
        assert_eq!(
            m.slab_freelist_hits + m.slab_fresh_bumps + m.slab_large_allocs,
            m.total_allocs,
            "{label}: shard {s} slab alloc sources do not cover total_allocs"
        );
        assert_eq!(
            m.slab_live_block_bytes, 0,
            "{label}: shard {s} slab blocks outlive their objects"
        );
        assert_eq!(
            m.slab_committed_bytes,
            m.slab_chunks * CHUNK_BYTES,
            "{label}: shard {s} committed bytes disagree with chunk count"
        );
        assert!(
            m.slab_committed_peak_bytes >= m.slab_committed_bytes,
            "{label}: shard {s} committed peak below the current gauge"
        );
        // Raw-path (memo/label storage) consistency: every shard routes
        // its label vector (and any memo buckets) through the allocator's
        // raw path, frees never outnumber allocations, and the label
        // vector's backing block is still held at the end of the run.
        assert!(
            m.slab_raw_allocs > 0,
            "{label}: shard {s} memo/label storage bypassed the slab raw path"
        );
        assert!(
            m.slab_raw_frees < m.slab_raw_allocs,
            "{label}: shard {s} raw alloc/free imbalance (label vec must stay live)"
        );
        match cfg.allocator {
            AllocatorKind::System => {
                assert_eq!(m.slab_chunks, 0, "{label}: system backend committed chunks");
                assert_eq!(m.slab_freelist_hits, 0, "{label}: system backend hit a free list");
                assert_eq!(
                    m.slab_raw_bytes, 0,
                    "{label}: system backend put raw blocks in slabs"
                );
                assert_eq!(
                    m.decommitted_chunks, 0,
                    "{label}: system backend has no chunks to decommit"
                );
            }
            AllocatorKind::Slab => {
                assert_eq!(
                    m.slab_large_allocs, 0,
                    "{label}: shard {s} model payloads must fit the size classes"
                );
            }
        }
        match cfg.decommit_watermark {
            None => assert_eq!(
                m.decommitted_chunks, 0,
                "{label}: shard {s} decommitted with the watermark off"
            ),
            Some(_) => assert_eq!(
                m.decommitted_bytes,
                m.decommitted_chunks * CHUNK_BYTES,
                "{label}: shard {s} decommit byte/chunk accounting disagrees"
            ),
        }
        // Large-object-space balance: reuses and frees can never outrun
        // allocations, and a fully-freed LOS carries no live bytes.
        assert!(
            m.los_reuses <= m.los_allocs,
            "{label}: shard {s} LOS reuses outnumber allocs"
        );
        assert!(
            m.los_frees <= m.los_allocs,
            "{label}: shard {s} LOS frees outnumber allocs"
        );
        if m.los_allocs == m.los_frees {
            assert_eq!(
                m.los_live_bytes, 0,
                "{label}: shard {s} LOS live-byte gauge drift at balance"
            );
        }
        match cfg.evacuate_threshold {
            None => assert_eq!(
                m.evacuated_objects + m.evacuated_chunks,
                0,
                "{label}: shard {s} evacuated with the barrier off"
            ),
            Some(_) => assert!(
                m.evacuated_bytes >= m.evacuated_objects * 16,
                "{label}: shard {s} evacuated objects without block bytes"
            ),
        }
        // And the allocator's own invariant sweep — per-chunk liveness
        // recounts, free-list integrity, avail-stack membership — in
        // every cell, not just the dedicated heap tests.
        h.validate_storage();
    }
    assert!(
        r.global_peak_bytes <= r.peak_bytes,
        "{label}: global peak {} above sum-of-peaks {}",
        r.global_peak_bytes,
        r.peak_bytes
    );
    assert!(r.global_peak_bytes > 0, "{label}: no peak recorded");
    if k == 1 {
        assert_eq!(
            r.global_peak_bytes, r.peak_bytes,
            "{label}: K=1 continuous peak is the exact global peak"
        );
        assert_eq!(r.migrations, 0, "{label}: K=1 cannot migrate");
        assert_eq!(r.steals, 0, "{label}: K=1 cannot steal");
    }
    Fingerprint {
        log_evidence: r.log_evidence.to_bits(),
        posterior_mean: r.posterior_mean.to_bits(),
        attempts: r.attempts,
    }
}

/// Sweep K ∈ {1, 2, 4} × policy × steal on/off × copy mode for one model
/// and assert every cell reproduces the per-mode oracle (K = 1, steal
/// off, rebalancing off) bit for bit — and that the oracle itself is
/// identical across copy modes (the paper's §4 matched-seed contract).
fn assert_bitwise_equiv<M: SmcModel + Sync>(
    name: &str,
    model: &M,
    base_cfg: &RunConfig,
    method: Method,
) {
    let pool = ThreadPool::new(4);
    let mut cross_mode: Option<Fingerprint> = None;
    for mode in CopyMode::ALL {
        let mut oracle_cfg = base_cfg.clone();
        oracle_cfg.mode = mode;
        oracle_cfg.steal = false;
        oracle_cfg.rebalance = RebalancePolicy::Off;
        let oracle = run_cell(
            model,
            &oracle_cfg,
            method,
            &pool,
            1,
            &format!("{name}/{mode:?}/oracle"),
        );
        match cross_mode {
            None => cross_mode = Some(oracle),
            Some(first) => assert_eq!(
                first, oracle,
                "{name}: oracle differs between copy modes at {mode:?}"
            ),
        }
        for k in [1usize, 2, 4] {
            for policy in RebalancePolicy::ALL {
                for steal in [false, true] {
                    let mut cfg = base_cfg.clone();
                    cfg.mode = mode;
                    cfg.rebalance = policy;
                    cfg.steal = steal;
                    // Force stealing to actually trigger when enabled:
                    // with the tiny test populations, the default
                    // threshold rarely leaves enough tail to donate.
                    cfg.steal_min = 2;
                    let label = format!(
                        "{name}/{mode:?}/K={k}/{policy:?}/steal={}",
                        if steal { "on" } else { "off" }
                    );
                    let got = run_cell(model, &cfg, method, &pool, k, &label);
                    assert_eq!(got, oracle, "{label}: output diverged from oracle");
                }
            }
        }
        // Payload-allocator axis: the matrix above runs on the default
        // `slab` backend; sweep `system` over K × steal on/off (policy
        // greedy) in one copy mode and demand the same oracle — the
        // allocator must never change what is computed. One mode
        // suffices: the allocator sits below the copy machinery, and the
        // cross-mode oracle equality above covers the rest.
        if mode == CopyMode::LazySro {
            for k in [1usize, 2, 4] {
                for steal in [false, true] {
                    let mut cfg = base_cfg.clone();
                    cfg.mode = mode;
                    cfg.allocator = AllocatorKind::System;
                    cfg.rebalance = RebalancePolicy::Greedy;
                    cfg.steal = steal;
                    cfg.steal_min = 2;
                    let label = format!(
                        "{name}/{mode:?}/system-alloc/K={k}/steal={}",
                        if steal { "on" } else { "off" }
                    );
                    let got = run_cell(model, &cfg, method, &pool, k, &label);
                    assert_eq!(got, oracle, "{label}: allocator changed the output");
                }
            }
            // Batched-numerics axis: the matrix above runs with the SoA
            // batch path on (the default); `--batch off` forces the
            // scalar per-particle reference path in every cell and must
            // reproduce the (batch-on) oracle bit for bit — the
            // `SmcModel::step_batched` contract, swept across the full
            // scheduling matrix plus a system-allocator cell per K.
            for k in [1usize, 2, 4] {
                for policy in RebalancePolicy::ALL {
                    for steal in [false, true] {
                        let mut cfg = base_cfg.clone();
                        cfg.mode = mode;
                        cfg.batch = false;
                        cfg.rebalance = policy;
                        cfg.steal = steal;
                        cfg.steal_min = 2;
                        let label = format!(
                            "{name}/{mode:?}/batch-off/K={k}/{policy:?}/steal={}",
                            if steal { "on" } else { "off" }
                        );
                        let got = run_cell(model, &cfg, method, &pool, k, &label);
                        assert_eq!(got, oracle, "{label}: batch toggle changed the output");
                    }
                }
                let mut cfg = base_cfg.clone();
                cfg.mode = mode;
                cfg.batch = false;
                cfg.allocator = AllocatorKind::System;
                cfg.rebalance = RebalancePolicy::Greedy;
                cfg.steal = true;
                cfg.steal_min = 2;
                let label = format!("{name}/{mode:?}/batch-off/system-alloc/K={k}");
                let got = run_cell(model, &cfg, method, &pool, k, &label);
                assert_eq!(got, oracle, "{label}: batch toggle changed the output");
            }
            // Decommit axis: the matrix above runs at the default
            // keep-2 watermark; `off` (never trim) and `0` (trim every
            // empty chunk, the most aggressive barrier) must reproduce
            // the oracle bit for bit — decommit only changes where
            // chunk memory lives, never what is computed.
            for wm in [None, Some(0usize)] {
                for k in [1usize, 4] {
                    let mut cfg = base_cfg.clone();
                    cfg.mode = mode;
                    cfg.decommit_watermark = wm;
                    cfg.rebalance = RebalancePolicy::Greedy;
                    cfg.steal = true;
                    cfg.steal_min = 2;
                    let wm_name = wm.map(|w| w.to_string()).unwrap_or_else(|| "off".into());
                    let label = format!("{name}/{mode:?}/decommit={wm_name}/K={k}");
                    let got = run_cell(model, &cfg, method, &pool, k, &label);
                    assert_eq!(got, oracle, "{label}: decommit changed the output");
                }
            }
            // Evacuation axis: the matrix above runs with the barrier
            // off (the default); threshold 0 (arms the barrier but never
            // selects a victim) and 0.5 (placement-moves every sparse
            // chunk's survivors at every generation) relocate payload
            // storage mid-run and must still reproduce the no-evacuation
            // oracle bit for bit — relocation may never change one bit
            // of output.
            for evac in [0.0f64, 0.5] {
                for k in [1usize, 4] {
                    for steal in [false, true] {
                        let mut cfg = base_cfg.clone();
                        cfg.mode = mode;
                        cfg.evacuate_threshold = Some(evac);
                        cfg.rebalance = RebalancePolicy::Greedy;
                        cfg.steal = steal;
                        cfg.steal_min = 2;
                        let label = format!(
                            "{name}/{mode:?}/evacuate={evac}/K={k}/steal={}",
                            if steal { "on" } else { "off" }
                        );
                        let got = run_cell(model, &cfg, method, &pool, k, &label);
                        assert_eq!(got, oracle, "{label}: evacuation changed the output");
                    }
                }
            }
        }
    }
    // Thread-count invariance: the same matrix cell on a different pool
    // (chunked propagation + stealing schedule both change) must still
    // reproduce the oracle.
    let pool2 = ThreadPool::new(2);
    let mut cfg = base_cfg.clone();
    cfg.rebalance = RebalancePolicy::Greedy;
    cfg.steal = true;
    cfg.steal_min = 2;
    let mut sh = ShardedHeap::new(cfg.mode, 4);
    let r = run_filter_shards(model, &cfg, sh.shards_mut(), &ctx(&pool2), method);
    let oracle = cross_mode.expect("oracle recorded");
    // base_cfg.mode is the first CopyMode::ALL entry's oracle only if the
    // modes agree — which the loop above asserted — so any mode works.
    assert_eq!(
        r.log_evidence.to_bits(),
        oracle.log_evidence,
        "{name}: output depends on worker-thread count"
    );
    assert_eq!(r.attempts, oracle.attempts, "{name}: attempts depend on threads");
}

#[test]
fn lgss_matrix_bitwise() {
    let model = ListModel::synthetic(25, 11);
    let exact = model.exact_evidence();
    let mut cfg = RunConfig::for_model(Model::List, Task::Inference, CopyMode::LazySro);
    cfg.n_particles = 96;
    cfg.n_steps = 25;
    cfg.seed = 2026_0730;
    // Statistical sanity against the closed-form Kalman evidence, so the
    // matrix isn't pinning a degenerate filter.
    let pool = ThreadPool::new(4);
    let mut sh = ShardedHeap::new(CopyMode::LazySro, 1);
    let mut oracle_cfg = cfg.clone();
    oracle_cfg.steal = false;
    oracle_cfg.rebalance = RebalancePolicy::Off;
    let r = run_filter_shards(&model, &oracle_cfg, sh.shards_mut(), &ctx(&pool), Method::Bootstrap);
    assert!(
        (r.log_evidence - exact).abs() < 3.0,
        "baseline {} vs oracle {exact}",
        r.log_evidence
    );
    assert_bitwise_equiv("lgss", &model, &cfg, Method::Bootstrap);
}

#[test]
fn pcfg_matrix_bitwise() {
    let model = Pcfg::synthetic(16, 7);
    let mut cfg = RunConfig::for_model(Model::Pcfg, Task::Inference, CopyMode::LazySro);
    cfg.n_particles = 48;
    cfg.n_steps = 16;
    cfg.seed = 42;
    assert_bitwise_equiv("pcfg", &model, &cfg, Method::Auxiliary);
}

#[test]
fn crbd_matrix_bitwise() {
    let model = Crbd::synthetic(25, 2);
    let mut cfg = RunConfig::for_model(Model::Crbd, Task::Inference, CopyMode::LazySro);
    cfg.n_particles = 48;
    cfg.n_steps = model.horizon();
    cfg.seed = 3;
    assert_bitwise_equiv("crbd", &model, &cfg, Method::Alive);
}

/// Drive a [`FilterSession`] by hand — begin, step every generation,
/// finish — instead of going through the `run_filter_shards` driver.
fn run_session_cell<M: SmcModel + Sync>(
    model: &M,
    cfg: &RunConfig,
    method: Method,
    pool: &ThreadPool,
    k: usize,
) -> Fingerprint {
    let mut sh = ShardedHeap::with_allocator(cfg.mode, k, cfg.allocator);
    let shards = sh.shards_mut();
    let c = ctx(pool);
    let t_max = cfg.n_steps.min(model.horizon());
    let mut session = FilterSession::begin(model, cfg, shards, &c, method);
    for _ in 0..t_max {
        session.step(model, shards, &c);
    }
    let r = session.finish(model, shards);
    assert_eq!(sh.live_objects(), 0, "session leaked live objects");
    Fingerprint {
        log_evidence: r.log_evidence.to_bits(),
        posterior_mean: r.posterior_mean.to_bits(),
        attempts: r.attempts,
    }
}

/// Session axis: a [`FilterSession`] stepped to completion is
/// bitwise-identical to `run_filter_shards` across K ∈ {1, 2, 4} ×
/// policy × steal × batch. The driver *is* a session internally, so this
/// pins the external step-at-a-time surface against it — any divergence
/// (a session method reordering a barrier, dropping a telemetry-side
/// effect into the hot path, forgetting the composed batch gate) breaks
/// here.
#[test]
fn lgss_session_axis_bitwise() {
    let model = ListModel::synthetic(20, 13);
    let mut base = RunConfig::for_model(Model::List, Task::Inference, CopyMode::LazySro);
    base.n_particles = 96;
    base.n_steps = 20;
    base.seed = 2026_0807;
    let pool = ThreadPool::new(4);
    for k in [1usize, 2, 4] {
        for policy in RebalancePolicy::ALL {
            for steal in [false, true] {
                for batch in [true, false] {
                    let mut cfg = base.clone();
                    cfg.rebalance = policy;
                    cfg.steal = steal;
                    cfg.steal_min = 2;
                    cfg.batch = batch;
                    let label = format!(
                        "lgss-session/K={k}/{policy:?}/steal={steal}/batch={batch}"
                    );
                    let driver = run_cell(&model, &cfg, Method::Bootstrap, &pool, k, &label);
                    let session = run_session_cell(&model, &cfg, Method::Bootstrap, &pool, k);
                    assert_eq!(session, driver, "{label}: session diverged from driver");
                }
            }
        }
    }
}

/// Session axis for the alive method: the adaptive speculative window
/// lives inside `alive_generation`, and both surfaces must agree on
/// outputs *and* attempt totals.
#[test]
fn crbd_session_axis_bitwise() {
    let model = Crbd::synthetic(25, 2);
    let mut cfg = RunConfig::for_model(Model::Crbd, Task::Inference, CopyMode::LazySro);
    cfg.n_particles = 48;
    cfg.n_steps = model.horizon();
    cfg.seed = 3;
    cfg.rebalance = RebalancePolicy::Greedy;
    cfg.steal_min = 2;
    let pool = ThreadPool::new(4);
    for k in [1usize, 2] {
        let driver = run_cell(&model, &cfg, Method::Alive, &pool, k, "crbd-session");
        let session = run_session_cell(&model, &cfg, Method::Alive, &pool, k);
        assert_eq!(session, driver, "crbd session K={k} diverged from driver");
    }
}

/// Fork contract: `fork()` performs **zero payload allocations and zero
/// eager copies** (pure lazy handle work, one `deep_copy` per particle,
/// asserted via allocator-metric scope deltas), the parent's outputs are
/// bitwise unchanged by having been forked, a fork stepped with the same
/// observations reproduces the unforked run bit for bit, and a fork
/// stepped with different observations diverges — independently of the
/// parent, on the same shards.
#[test]
fn session_fork_diverges_independently() {
    let t_max = 24;
    let split = 12;
    let n = 64;
    let model = ListModel::synthetic(t_max, 21);
    let mut cfg = RunConfig::for_model(Model::List, Task::Inference, CopyMode::LazySro);
    cfg.n_particles = n;
    cfg.n_steps = t_max;
    cfg.seed = 77;
    cfg.steal_min = 2;
    let pool = ThreadPool::new(4);
    let k = 2;

    // Oracle: the unforked run on fresh shards.
    let full = run_cell(&model, &cfg, Method::Bootstrap, &pool, k, "fork/oracle");

    // A counterfactual observation stream diverging after the fork point.
    let mut alt_model = model.clone();
    for y in &mut alt_model.obs[split..] {
        *y = -*y - 1.0;
    }

    let mut sh = ShardedHeap::new(CopyMode::LazySro, k);
    let shards = sh.shards_mut();
    let c = ctx(&pool);
    let mut parent = FilterSession::begin(&model, &cfg, shards, &c, Method::Bootstrap);
    for _ in 0..split {
        parent.step(&model, shards, &c);
    }

    // Fork twice under metric scopes: O(particles) lazy handle work only.
    let scopes: Vec<_> = shards.iter().map(|h| h.begin_scope()).collect();
    let mut fork_same = parent.fork(shards);
    let mut fork_diff = parent.fork(shards);
    let mut allocs = 0usize;
    let mut eager = 0usize;
    let mut deep = 0usize;
    for (h, scope) in shards.iter().zip(scopes) {
        let d = h.end_scope(scope);
        allocs += d.total_allocs;
        eager += d.eager_copies;
        deep += d.deep_copies;
    }
    assert_eq!(allocs, 0, "fork allocated payloads");
    assert_eq!(eager, 0, "fork copied eagerly");
    assert_eq!(deep, 2 * n, "fork must lazily deep-copy each particle once");

    // All three lineages run to the horizon on the shared shards.
    for _ in split..t_max {
        parent.step(&model, shards, &c);
        fork_same.step(&model, shards, &c);
        fork_diff.step(&alt_model, shards, &c);
    }
    let pr = parent.finish(&model, shards);
    let sr = fork_same.finish(&model, shards);
    let dr = fork_diff.finish(&alt_model, shards);

    assert_eq!(
        (pr.log_evidence.to_bits(), pr.posterior_mean.to_bits(), pr.attempts),
        (full.log_evidence, full.posterior_mean, full.attempts),
        "parent output changed by forking"
    );
    assert_eq!(
        (sr.log_evidence.to_bits(), sr.posterior_mean.to_bits(), sr.attempts),
        (full.log_evidence, full.posterior_mean, full.attempts),
        "same-observations fork diverged from the unforked run"
    );
    assert_ne!(
        dr.log_evidence.to_bits(),
        full.log_evidence,
        "counterfactual fork failed to diverge"
    );
    assert_eq!(sh.live_objects(), 0, "forked lineages leaked");
}

/// Every stable phase name the tracer can emit (the `trace::Phase`
/// contract, mirrored here so a rename breaks a test).
const TRACE_PHASES: [&str; 9] = [
    "propagate",
    "weight",
    "resample",
    "rebalance-plan",
    "transplant",
    "steal-donate",
    "scratch-reclaim",
    "evacuate",
    "trim",
];

/// Tracing axis: `--trace` must never influence computation. Every cell
/// of K ∈ {1, 2, 4} × policy × steal × batch run with a trace sink
/// attached is bitwise-identical to the untraced run, and the emitted
/// JSONL is well-formed — every line a span record carrying a known
/// phase name, a generation index, and a duration.
#[test]
fn lgss_trace_axis_bitwise() {
    let model = ListModel::synthetic(18, 17);
    let mut base = RunConfig::for_model(Model::List, Task::Inference, CopyMode::LazySro);
    base.n_particles = 96;
    base.n_steps = 18;
    base.seed = 2026_0807;
    let pool = ThreadPool::new(4);
    let dir = std::env::temp_dir();
    for k in [1usize, 2, 4] {
        for policy in RebalancePolicy::ALL {
            for steal in [false, true] {
                for batch in [true, false] {
                    let mut cfg = base.clone();
                    cfg.rebalance = policy;
                    cfg.steal = steal;
                    cfg.steal_min = 2;
                    cfg.batch = batch;
                    let off = run_session_cell(&model, &cfg, Method::Bootstrap, &pool, k);

                    let path = dir.join(format!(
                        "lazycow-trace-{}-{k}-{policy:?}-{steal}-{batch}.jsonl",
                        std::process::id()
                    ));
                    let _ = std::fs::remove_file(&path);
                    cfg.trace = Some(path.to_string_lossy().into_owned());
                    let on = run_session_cell(&model, &cfg, Method::Bootstrap, &pool, k);
                    assert_eq!(
                        on, off,
                        "K={k}/{policy:?}/steal={steal}/batch={batch}: tracing changed the output"
                    );

                    let text = std::fs::read_to_string(&path).expect("trace file written");
                    assert!(!text.is_empty(), "trace file empty");
                    for line in text.lines() {
                        assert!(line.starts_with("{\"session\":"), "bad span line: {line}");
                        assert!(line.ends_with('}'), "bad span line: {line}");
                        assert!(line.contains("\"t\":"), "span missing t: {line}");
                        assert!(line.contains("\"dur_s\":"), "span missing dur_s: {line}");
                        let phase = line
                            .split("\"phase\":\"")
                            .nth(1)
                            .and_then(|rest| rest.split('"').next())
                            .expect("span missing phase");
                        assert!(
                            TRACE_PHASES.contains(&phase),
                            "unknown phase {phase:?} in {line}"
                        );
                    }
                    let _ = std::fs::remove_file(&path);
                }
            }
        }
    }
}

/// Trace/metrics agreement: the spans flushed to the JSONL file and the
/// `phase_wall_seconds{phase=..}` histograms are fed from the same
/// clock reads, so per-phase file totals must equal the histogram sums
/// up to the span format's 1 ns rounding.
#[test]
fn trace_totals_match_phase_histograms() {
    let t_max = 15;
    let model = ListModel::synthetic(t_max, 23);
    let mut cfg = RunConfig::for_model(Model::List, Task::Inference, CopyMode::LazySro);
    cfg.n_particles = 64;
    cfg.n_steps = t_max;
    cfg.seed = 5;
    cfg.rebalance = RebalancePolicy::Greedy;
    cfg.steal = true;
    cfg.steal_min = 2;
    let path = std::env::temp_dir().join(format!(
        "lazycow-trace-agree-{}.jsonl",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    cfg.trace = Some(path.to_string_lossy().into_owned());
    let pool = ThreadPool::new(4);
    let mut sh = ShardedHeap::new(cfg.mode, 2);
    let shards = sh.shards_mut();
    let c = ctx(&pool);
    let mut session = FilterSession::begin(&model, &cfg, shards, &c, Method::Bootstrap);
    for _ in 0..t_max {
        session.step(&model, shards, &c);
    }
    let hist_sum = |phase: &str| -> f64 {
        session
            .telemetry()
            .histogram_with(lazycow::telemetry::PHASE_WALL_SECONDS, &[("phase", phase)])
            .map(|h| h.sum())
            .unwrap_or(0.0)
    };
    let hist: Vec<(String, f64)> = TRACE_PHASES
        .iter()
        .map(|p| (p.to_string(), hist_sum(p)))
        .collect();
    session.finish(&model, shards);

    let text = std::fs::read_to_string(&path).expect("trace file written");
    let mut file_sum = vec![0.0f64; TRACE_PHASES.len()];
    let mut spans = 0usize;
    for line in text.lines() {
        let phase = line
            .split("\"phase\":\"")
            .nth(1)
            .and_then(|rest| rest.split('"').next())
            .expect("phase field");
        let dur: f64 = line
            .split("\"dur_s\":")
            .nth(1)
            .map(|rest| rest.trim_end_matches('}'))
            .expect("dur_s field")
            .parse()
            .expect("dur_s parses");
        let i = TRACE_PHASES.iter().position(|p| *p == phase).expect("known phase");
        file_sum[i] += dur;
        spans += 1;
    }
    assert!(spans > 0, "no spans recorded");
    for (i, (phase, h)) in hist.iter().enumerate() {
        let tolerance = 1e-9 * (spans as f64) + 1e-9;
        assert!(
            (file_sum[i] - h).abs() <= tolerance,
            "phase {phase}: trace total {} vs histogram sum {h}",
            file_sum[i]
        );
    }
    let _ = std::fs::remove_file(&path);
}

/// Simulation (no observations, no resampling, no copies): the engine
/// gates stealing to inference, so even with `steal = true` the
/// simulation task stays bit-identical *and* copy-free — the Figure 6
/// contract holds with default configuration.
#[test]
fn simulation_matrix_bitwise() {
    let model = ListModel::synthetic(30, 5);
    let mut cfg = RunConfig::for_model(Model::List, Task::Simulation, CopyMode::LazySro);
    cfg.n_particles = 64;
    cfg.n_steps = 30;
    cfg.seed = 9;
    let pool = ThreadPool::new(4);
    let mut oracle_cfg = cfg.clone();
    oracle_cfg.steal = false;
    let mut sh = ShardedHeap::new(CopyMode::LazySro, 1);
    let base = run_filter_shards(&model, &oracle_cfg, sh.shards_mut(), &ctx(&pool), Method::Bootstrap);
    for steal in [false, true] {
        for batch in [true, false] {
            let mut c = cfg.clone();
            c.steal = steal;
            c.steal_min = 2;
            c.batch = batch;
            let mut sh = ShardedHeap::new(CopyMode::LazySro, 4);
            let r = run_filter_shards(&model, &c, sh.shards_mut(), &ctx(&pool), Method::Bootstrap);
            assert_eq!(r.posterior_mean.to_bits(), base.posterior_mean.to_bits());
            assert_eq!(sh.live_objects(), 0);
            assert_eq!(r.steals, 0, "stealing is gated to inference");
            let m = sh.metrics();
            assert_eq!(m.deep_copies, 0, "simulation never deep-copies");
            assert_eq!(m.eager_copies, 0, "simulation never copies");
            assert_eq!(m.transplants, 0, "simulation never transplants");
        }
    }
}
