//! Whole-system integration tests: the §4 validation (identical output in
//! every copy configuration, matched seeds), the theoretical memory
//! shapes, and the PJRT artifact path against the CPU oracle inside a full
//! filter run.

use lazycow::config::{Model, RunConfig, Task};
use lazycow::heap::{CopyMode, Heap, ShardedHeap};
use lazycow::models::{run_model, Rbpf, DATA_SEED};
use lazycow::pool::ThreadPool;
use lazycow::runtime::{BatchKalman, XlaRuntime};
use lazycow::smc::{run_filter, Method, StepCtx};

fn ctx(pool: &ThreadPool) -> StepCtx<'_> {
    StepCtx { pool, kalman: None, batch: true }
}

/// §4: "the output is expected to match regardless of the configuration;
/// a comparison of output files confirms that this is the case."
#[test]
fn output_identical_across_configurations() {
    let pool = ThreadPool::new(2);
    for model in Model::EVAL {
        let mut outs: Vec<(u64, u64)> = Vec::new();
        for mode in CopyMode::ALL {
            let mut cfg = RunConfig::for_model(model, Task::Inference, mode);
            cfg.n_particles = 48;
            cfg.n_steps = 20;
            cfg.pg_iterations = 2;
            cfg.seed = 123;
            let mut heap = ShardedHeap::new(mode, 1);
            let r = run_model(&cfg, &mut heap, &ctx(&pool));
            outs.push((r.log_evidence.to_bits(), r.posterior_mean.to_bits()));
            assert_eq!(heap.live_objects(), 0, "{model:?}/{mode:?} leaked");
        }
        assert_eq!(outs[0], outs[1], "{model:?}: eager != lazy");
        assert_eq!(outs[1], outs[2], "{model:?}: lazy != lazy-sro");
    }
}

/// The dense-vs-sparse storage contrast: eager peak memory grows with N·T
/// while lazy stays near O(T + N log N) (Jacob et al. 2015).
#[test]
fn memory_scaling_shapes() {
    let pool = ThreadPool::new(1);
    let run = |mode: CopyMode, t: usize| -> f64 {
        let mut cfg = RunConfig::for_model(Model::List, Task::Inference, mode);
        cfg.n_particles = 64;
        cfg.n_steps = t;
        let mut heap = ShardedHeap::new(mode, 1);
        let r = run_model(&cfg, &mut heap, &ctx(&pool));
        r.peak_bytes as f64
    };
    // Eager peak grows roughly linearly in T; lazy roughly flat.
    let (e1, e2) = (run(CopyMode::Eager, 50), run(CopyMode::Eager, 200));
    let (l1, l2) = (run(CopyMode::LazySro, 50), run(CopyMode::LazySro, 200));
    assert!(e2 > e1 * 2.5, "eager peak should scale with T: {e1} -> {e2}");
    assert!(l2 < l1 * 2.0, "lazy peak should stay near-flat: {l1} -> {l2}");
    assert!(l2 < e2 / 4.0, "lazy must undercut eager at T=200");
}

/// Eager execution time grows superlinearly with T (quadratic copying);
/// lazy stays linear — the Figure 7 contrast.
#[test]
fn time_scaling_shapes() {
    let pool = ThreadPool::new(1);
    let run = |mode: CopyMode, t: usize| -> f64 {
        let mut cfg = RunConfig::for_model(Model::List, Task::Inference, mode);
        cfg.n_particles = 64;
        cfg.n_steps = t;
        let mut heap = ShardedHeap::new(mode, 1);
        run_model(&cfg, &mut heap, &ctx(&pool)).wall_s
    };
    // Warm up + measure.
    let _ = run(CopyMode::Eager, 50);
    let e_ratio = run(CopyMode::Eager, 400) / run(CopyMode::Eager, 100).max(1e-9);
    let l_ratio = run(CopyMode::LazySro, 400) / run(CopyMode::LazySro, 100).max(1e-9);
    // 4x more generations: eager should blow well past 4x (quadratic term),
    // lazy should stay near 4x.
    assert!(e_ratio > 6.0, "eager time ratio {e_ratio} not superlinear");
    assert!(l_ratio < 8.0, "lazy time ratio {l_ratio} far from linear");
}

/// The XLA artifact path and CPU oracle path produce closely matching
/// filter outputs (f32 vs f64 tolerance) within a full RBPF run.
#[test]
fn xla_and_cpu_paths_agree() {
    let rt = match XlaRuntime::cpu("artifacts") {
        Ok(rt) if rt.has_artifact("kalman3") => rt,
        _ => {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
    };
    let bk = BatchKalman::load(&rt).expect("load artifact");
    let pool = ThreadPool::new(2);
    let model = Rbpf::synthetic(40, DATA_SEED);
    let mut cfg = RunConfig::for_model(Model::Rbpf, Task::Inference, CopyMode::LazySro);
    cfg.n_particles = 256;
    cfg.n_steps = 40;

    let mut heap = Heap::new(CopyMode::LazySro);
    let cpu_ctx = StepCtx {
        pool: &pool,
        kalman: None,
        batch: true,
    };
    let r_cpu = run_filter(&model, &cfg, &mut heap, &cpu_ctx, Method::Bootstrap);

    let mut heap = Heap::new(CopyMode::LazySro);
    let xla_ctx = StepCtx {
        pool: &pool,
        kalman: Some(&bk),
        batch: true,
    };
    let r_xla = run_filter(&model, &cfg, &mut heap, &xla_ctx, Method::Bootstrap);

    let diff = (r_cpu.log_evidence - r_xla.log_evidence).abs();
    let rel = diff / r_cpu.log_evidence.abs().max(1.0);
    assert!(
        rel < 1e-3,
        "CPU {} vs XLA {} (rel {rel})",
        r_cpu.log_evidence,
        r_xla.log_evidence
    );
}

/// Simulation task performs zero copies in every model (the paper's
/// Figure 6 premise).
#[test]
fn simulation_never_copies() {
    let pool = ThreadPool::new(1);
    for model in Model::EVAL {
        let mut cfg = RunConfig::for_model(model, Task::Simulation, CopyMode::LazySro);
        cfg.n_particles = 16;
        cfg.n_steps = 15;
        // steal stays at its default (on): the engine gates stealing to
        // inference, so the simulation task's zero-copy contract must
        // hold without any opt-out.
        let mut heap = ShardedHeap::new(CopyMode::LazySro, 2);
        let _ = run_model(&cfg, &mut heap, &ctx(&pool));
        let m = heap.metrics();
        assert_eq!(m.deep_copies, 0, "{model:?} copied in simulation");
        assert_eq!(m.lazy_copies, 0);
        assert_eq!(m.eager_copies, 0);
        assert_eq!(m.transplants, 0, "{model:?} transplanted in simulation");
    }
}
