//! Cross-shard lineage transplant and rebalancing: structural invariants
//! (heap-metrics balance after transplants/migrations, exact global-peak
//! bounds) plus particle-Gibbs shard equivalence.
//!
//! The K × policy × steal × copy-mode bitwise-equivalence matrix lives in
//! `tests/differential.rs` (the reusable `assert_bitwise_equiv` runner);
//! alive-PF stream-contract coverage lives in `tests/alive_contract.rs`.

use lazycow::config::{Model, RunConfig, Task};
use lazycow::heap::{shard_of, CopyMode, Heap, ShardedHeap};
use lazycow::models::ListModel;
use lazycow::pool::ThreadPool;
use lazycow::smc::{
    run_filter, run_filter_shards, run_particle_gibbs, run_particle_gibbs_shards, Method,
    RebalancePolicy, StepCtx,
};

fn ctx(pool: &ThreadPool) -> StepCtx<'_> {
    StepCtx { pool, kalman: None, batch: true }
}

fn lgss_cfg(n: usize, t: usize) -> RunConfig {
    let mut cfg = RunConfig::for_model(Model::List, Task::Inference, CopyMode::LazySro);
    cfg.n_particles = n;
    cfg.n_steps = t;
    cfg.seed = 2026_0730;
    cfg
}

/// The static partition's boundary crossings still happen (and are still
/// counted as transplants) with rebalancing off — the one piece of the
/// old matrix that is about *metrics*, not output identity, so it stays
/// here rather than in the differential harness.
#[test]
fn static_partition_crosses_shard_boundaries() {
    let model = ListModel::synthetic(30, 11);
    let pool = ThreadPool::new(4);
    let mut cfg = lgss_cfg(96, 30);
    cfg.rebalance = RebalancePolicy::Off;
    cfg.steal = false;
    let mut sh = ShardedHeap::new(CopyMode::LazySro, 4);
    let _ = run_filter_shards(&model, &cfg, sh.shards_mut(), &ctx(&pool), Method::Bootstrap);
    assert!(
        sh.metrics().transplants > 0,
        "systematic resampling over a static partition must cross shard boundaries"
    );
}

/// With a zero imbalance threshold and skewed per-particle costs the
/// greedy planner must actually migrate — and the per-shard alloc/free
/// balance and bitwise output equivalence must survive those migrations.
#[test]
fn forced_migrations_keep_balance_and_output() {
    let model = ListModel::synthetic(30, 19);
    let pool = ThreadPool::new(4);
    let mut cfg = lgss_cfg(96, 30);

    let mut baseline = Heap::new(CopyMode::LazySro);
    let base = run_filter(&model, &cfg, &mut baseline, &ctx(&pool), Method::Bootstrap);

    cfg.rebalance = RebalancePolicy::Greedy;
    cfg.rebalance_threshold = 0.0; // any imbalance migrates
    let mut sh = ShardedHeap::new(CopyMode::LazySro, 4);
    let r = run_filter_shards(&model, &cfg, sh.shards_mut(), &ctx(&pool), Method::Bootstrap);
    assert_eq!(r.log_evidence.to_bits(), base.log_evidence.to_bits());
    assert_eq!(r.posterior_mean.to_bits(), base.posterior_mean.to_bits());
    assert!(
        r.migrations > 0,
        "zero threshold over 30 resampling steps must migrate at least once"
    );
    assert_eq!(sh.live_objects(), 0, "migrations leaked");
    for (s, h) in sh.shards().iter().enumerate() {
        assert_eq!(
            h.metrics.total_allocs,
            h.metrics.total_frees + h.metrics.live_objects,
            "shard {s}: balance broken after migrations"
        );
    }
}

/// Per-shard metrics balance holds on every shard individually, not just
/// in aggregate — a transplant allocates on the destination and frees on
/// neither.
#[test]
fn per_shard_alloc_free_balance() {
    let model = ListModel::synthetic(30, 5);
    let pool = ThreadPool::new(2);
    let cfg = lgss_cfg(100, 30);
    let mut sh = ShardedHeap::new(CopyMode::LazySro, 4);
    let _ = run_filter_shards(&model, &cfg, sh.shards_mut(), &ctx(&pool), Method::Bootstrap);
    for (s, h) in sh.shards().iter().enumerate() {
        assert_eq!(
            h.metrics.total_allocs,
            h.metrics.total_frees + h.metrics.live_objects,
            "shard {s}: balance broken"
        );
        assert_eq!(h.live_objects(), 0, "shard {s} leaked");
    }
    let agg = sh.metrics();
    assert_eq!(agg.total_allocs, agg.total_frees);
}

/// Particle Gibbs over shards: the reference trajectory lives on the
/// conditional slot's shard and winners are transplanted there; per-
/// iteration output must match the single-heap run bit-for-bit.
#[test]
fn particle_gibbs_shard_counts_match_single_heap() {
    let model = ListModel::synthetic(20, 13);
    let pool = ThreadPool::new(3);
    let mut cfg = lgss_cfg(48, 20);
    cfg.pg_iterations = 3;

    let mut baseline = Heap::new(CopyMode::LazySro);
    let base = run_particle_gibbs(&model, &cfg, &mut baseline, &ctx(&pool));
    assert_eq!(baseline.live_objects(), 0);

    for k in [2usize, 4] {
        for steal in [false, true] {
            let mut cfg = cfg.clone();
            cfg.steal = steal;
            cfg.steal_min = 2;
            let mut sh = ShardedHeap::new(CopyMode::LazySro, k);
            let rs = run_particle_gibbs_shards(&model, &cfg, sh.shards_mut(), &ctx(&pool));
            assert_eq!(rs.len(), base.len());
            for (i, (r, b)) in rs.iter().zip(&base).enumerate() {
                assert_eq!(
                    r.log_evidence.to_bits(),
                    b.log_evidence.to_bits(),
                    "K={k} steal={steal} iter {i}: evidence differs"
                );
                assert_eq!(
                    r.posterior_mean.to_bits(),
                    b.posterior_mean.to_bits(),
                    "K={k} steal={steal} iter {i}: posterior differs"
                );
            }
            assert_eq!(sh.live_objects(), 0, "K={k} steal={steal} leaked");
            let m = sh.metrics();
            assert_eq!(m.total_allocs, m.total_frees + m.live_objects);
            assert!(m.eager_copies > 0, "reference copies must be eager");
        }
    }
}

/// Degenerate partitions: more shards than particles, and K exactly N.
#[test]
fn more_shards_than_particles() {
    let model = ListModel::synthetic(10, 17);
    let pool = ThreadPool::new(2);
    let mut cfg = lgss_cfg(6, 10);
    cfg.seed = 5;

    let mut baseline = Heap::new(CopyMode::LazySro);
    let base = run_filter(&model, &cfg, &mut baseline, &ctx(&pool), Method::Bootstrap);

    for k in [6usize, 9] {
        let mut sh = ShardedHeap::new(CopyMode::LazySro, k);
        let r = run_filter_shards(
            &model,
            &cfg,
            sh.shards_mut(),
            &ctx(&pool),
            Method::Bootstrap,
        );
        assert_eq!(r.log_evidence.to_bits(), base.log_evidence.to_bits());
        assert_eq!(sh.live_objects(), 0);
    }
}

/// Sanity on the partition helper used throughout the engine: the
/// contiguous layout means most systematic-resampling offspring stay on
/// their ancestor's shard (boundary crossings are the exception the
/// transplant handles).
#[test]
fn shard_of_is_consistent_with_contiguous_layout() {
    for (n, k) in [(192usize, 4usize), (100, 3), (6, 9)] {
        for i in 0..n {
            let s = shard_of(n, k, i);
            assert!(s < k);
            if i > 0 {
                assert!(s >= shard_of(n, k, i - 1), "shards must be monotone in i");
            }
        }
    }
}
