//! Cross-shard lineage transplant and rebalancing: seeded equivalence
//! across shard counts *and rebalance policies* against the single-heap
//! baseline and the closed-form LGSS oracle, plus heap-metrics balance
//! after transplants/migrations and the exact global-peak invariants.

use lazycow::config::{Model, RunConfig, Task};
use lazycow::heap::{shard_of, CopyMode, Heap, ShardedHeap};
use lazycow::models::{Crbd, ListModel};
use lazycow::pool::ThreadPool;
use lazycow::smc::{
    run_filter, run_filter_shards, run_particle_gibbs, run_particle_gibbs_shards, Method,
    RebalancePolicy, SmcModel, StepCtx,
};

fn ctx(pool: &ThreadPool) -> StepCtx<'_> {
    StepCtx { pool, kalman: None }
}

fn lgss_cfg(n: usize, t: usize) -> RunConfig {
    let mut cfg = RunConfig::for_model(Model::List, Task::Inference, CopyMode::LazySro);
    cfg.n_particles = n;
    cfg.n_steps = t;
    cfg.seed = 2026_0730;
    cfg
}

/// The full equivalence matrix: rebalance policy × K ∈ {1, 2, 4} × copy
/// mode on the LGSS oracle model (a 1-D linear-Gaussian SSM with exact
/// Kalman evidence). Every cell must reproduce the single-heap baseline
/// bit-for-bit — rebalancing moves heap work between shards, never what
/// is computed — and stay close to the oracle.
#[test]
fn lgss_policy_shard_mode_matrix_bitwise() {
    let model = ListModel::synthetic(40, 11);
    let exact = model.exact_evidence();
    let pool = ThreadPool::new(4);
    let cfg = lgss_cfg(192, 40);

    let mut baseline = Heap::new(CopyMode::LazySro);
    let base = run_filter(&model, &cfg, &mut baseline, &ctx(&pool), Method::Bootstrap);
    assert!(
        (base.log_evidence - exact).abs() < 3.0,
        "baseline {} vs oracle {exact}",
        base.log_evidence
    );
    assert_eq!(baseline.live_objects(), 0);

    for policy in RebalancePolicy::ALL {
        for mode in CopyMode::ALL {
            for k in [1usize, 2, 4] {
                let mut cfg = cfg.clone();
                cfg.mode = mode;
                cfg.rebalance = policy;
                let mut sh = ShardedHeap::new(mode, k);
                let r = run_filter_shards(
                    &model,
                    &cfg,
                    sh.shards_mut(),
                    &ctx(&pool),
                    Method::Bootstrap,
                );
                assert_eq!(
                    r.log_evidence.to_bits(),
                    base.log_evidence.to_bits(),
                    "{policy:?}/{mode:?}/K={k}: log_evidence differs from baseline"
                );
                assert_eq!(
                    r.posterior_mean.to_bits(),
                    base.posterior_mean.to_bits(),
                    "{policy:?}/{mode:?}/K={k}: posterior_mean differs from baseline"
                );
                assert_eq!(sh.live_objects(), 0, "{policy:?}/{mode:?}/K={k} leaked");
                let m = sh.metrics();
                assert_eq!(
                    m.total_allocs,
                    m.total_frees + m.live_objects,
                    "{policy:?}/{mode:?}/K={k}: alloc/free/live balance broken"
                );
                // Exact global peak never exceeds the sum-of-peaks bound,
                // and both are reported.
                assert!(
                    r.global_peak_bytes <= r.peak_bytes,
                    "{policy:?}/{mode:?}/K={k}: global peak {} above sum-of-peaks {}",
                    r.global_peak_bytes,
                    r.peak_bytes
                );
                assert!(r.global_peak_bytes > 0);
                if k == 1 {
                    assert_eq!(
                        r.global_peak_bytes, r.peak_bytes,
                        "K=1: the continuous peak is the exact global peak"
                    );
                    assert_eq!(r.migrations, 0, "K=1 can never migrate");
                }
                if k > 1 && mode.is_lazy() && policy == RebalancePolicy::Off {
                    assert!(
                        m.transplants > 0,
                        "{mode:?} K={k}: static partition never crossed a shard boundary"
                    );
                }
            }
        }
    }
}

/// With a zero imbalance threshold and skewed per-particle costs the
/// greedy planner must actually migrate — and the per-shard alloc/free
/// balance and bitwise output equivalence must survive those migrations.
#[test]
fn forced_migrations_keep_balance_and_output() {
    let model = ListModel::synthetic(30, 19);
    let pool = ThreadPool::new(4);
    let mut cfg = lgss_cfg(96, 30);

    let mut baseline = Heap::new(CopyMode::LazySro);
    let base = run_filter(&model, &cfg, &mut baseline, &ctx(&pool), Method::Bootstrap);

    cfg.rebalance = RebalancePolicy::Greedy;
    cfg.rebalance_threshold = 0.0; // any imbalance migrates
    let mut sh = ShardedHeap::new(CopyMode::LazySro, 4);
    let r = run_filter_shards(&model, &cfg, sh.shards_mut(), &ctx(&pool), Method::Bootstrap);
    assert_eq!(r.log_evidence.to_bits(), base.log_evidence.to_bits());
    assert_eq!(r.posterior_mean.to_bits(), base.posterior_mean.to_bits());
    assert!(
        r.migrations > 0,
        "zero threshold over 30 resampling steps must migrate at least once"
    );
    assert_eq!(sh.live_objects(), 0, "migrations leaked");
    for (s, h) in sh.shards().iter().enumerate() {
        assert_eq!(
            h.metrics.total_allocs,
            h.metrics.total_frees + h.metrics.live_objects,
            "shard {s}: balance broken after migrations"
        );
    }
}

/// Per-shard metrics balance holds on every shard individually, not just
/// in aggregate — a transplant allocates on the destination and frees on
/// neither.
#[test]
fn per_shard_alloc_free_balance() {
    let model = ListModel::synthetic(30, 5);
    let pool = ThreadPool::new(2);
    let cfg = lgss_cfg(100, 30);
    let mut sh = ShardedHeap::new(CopyMode::LazySro, 4);
    let _ = run_filter_shards(&model, &cfg, sh.shards_mut(), &ctx(&pool), Method::Bootstrap);
    for (s, h) in sh.shards().iter().enumerate() {
        assert_eq!(
            h.metrics.total_allocs,
            h.metrics.total_frees + h.metrics.live_objects,
            "shard {s}: balance broken"
        );
        assert_eq!(h.live_objects(), 0, "shard {s} leaked");
    }
    let agg = sh.metrics();
    assert_eq!(agg.total_allocs, agg.total_frees);
}

/// Particle Gibbs over shards: the reference trajectory lives on the
/// conditional slot's shard and winners are transplanted there; per-
/// iteration output must match the single-heap run bit-for-bit.
#[test]
fn particle_gibbs_shard_counts_match_single_heap() {
    let model = ListModel::synthetic(20, 13);
    let pool = ThreadPool::new(3);
    let mut cfg = lgss_cfg(48, 20);
    cfg.pg_iterations = 3;

    let mut baseline = Heap::new(CopyMode::LazySro);
    let base = run_particle_gibbs(&model, &cfg, &mut baseline, &ctx(&pool));
    assert_eq!(baseline.live_objects(), 0);

    for k in [2usize, 4] {
        let mut sh = ShardedHeap::new(CopyMode::LazySro, k);
        let rs = run_particle_gibbs_shards(&model, &cfg, sh.shards_mut(), &ctx(&pool));
        assert_eq!(rs.len(), base.len());
        for (i, (r, b)) in rs.iter().zip(&base).enumerate() {
            assert_eq!(
                r.log_evidence.to_bits(),
                b.log_evidence.to_bits(),
                "K={k} iter {i}: evidence differs"
            );
            assert_eq!(
                r.posterior_mean.to_bits(),
                b.posterior_mean.to_bits(),
                "K={k} iter {i}: posterior differs"
            );
        }
        assert_eq!(sh.live_objects(), 0, "K={k} leaked");
        let m = sh.metrics();
        assert_eq!(m.total_allocs, m.total_frees + m.live_objects);
        assert!(m.eager_copies > 0, "reference copies must be eager");
    }
}

/// The alive PF is coordinator-serial, so the engine collapses its
/// population onto shard 0 (a sharded layout would make the O(history)
/// transplant the common case on retries): results must match the
/// single-heap run exactly — including the attempt count — with zero
/// transplants.
#[test]
fn alive_filter_shard_counts_match_single_heap() {
    let model = Crbd::synthetic(30, 2);
    let pool = ThreadPool::new(2);
    let mut cfg = RunConfig::for_model(Model::Crbd, Task::Inference, CopyMode::LazySro);
    cfg.n_particles = 64;
    cfg.n_steps = model.horizon();
    cfg.seed = 3;

    let mut baseline = Heap::new(CopyMode::LazySro);
    let base = run_filter(&model, &cfg, &mut baseline, &ctx(&pool), Method::Alive);

    for k in [2usize, 3] {
        let mut sh = ShardedHeap::new(CopyMode::LazySro, k);
        let r = run_filter_shards(&model, &cfg, sh.shards_mut(), &ctx(&pool), Method::Alive);
        assert_eq!(r.log_evidence.to_bits(), base.log_evidence.to_bits());
        assert_eq!(r.posterior_mean.to_bits(), base.posterior_mean.to_bits());
        assert_eq!(r.attempts, base.attempts, "K={k}: attempt counts differ");
        assert_eq!(sh.live_objects(), 0, "K={k} leaked");
        assert_eq!(
            sh.metrics().transplants,
            0,
            "K={k}: alive PF must stay on one shard"
        );
    }
}

/// Degenerate partitions: more shards than particles, and K exactly N.
#[test]
fn more_shards_than_particles() {
    let model = ListModel::synthetic(10, 17);
    let pool = ThreadPool::new(2);
    let mut cfg = lgss_cfg(6, 10);
    cfg.seed = 5;

    let mut baseline = Heap::new(CopyMode::LazySro);
    let base = run_filter(&model, &cfg, &mut baseline, &ctx(&pool), Method::Bootstrap);

    for k in [6usize, 9] {
        let mut sh = ShardedHeap::new(CopyMode::LazySro, k);
        let r = run_filter_shards(
            &model,
            &cfg,
            sh.shards_mut(),
            &ctx(&pool),
            Method::Bootstrap,
        );
        assert_eq!(r.log_evidence.to_bits(), base.log_evidence.to_bits());
        assert_eq!(sh.live_objects(), 0);
    }
}

/// Sanity on the partition helper used throughout the engine: the
/// contiguous layout means most systematic-resampling offspring stay on
/// their ancestor's shard (boundary crossings are the exception the
/// transplant handles).
#[test]
fn shard_of_is_consistent_with_contiguous_layout() {
    for (n, k) in [(192usize, 4usize), (100, 3), (6, 9)] {
        for i in 0..n {
            let s = shard_of(n, k, i);
            assert!(s < k);
            if i > 0 {
                assert!(s >= shard_of(n, k, i - 1), "shards must be monotone in i");
            }
        }
    }
}
