//! Alive-PF stream-contract tests (contract v2: per-slot retry streams).
//!
//! The contract: attempt `a` of slot `i` at generation `t` consumes
//! `alive_retry_rng(seed, t, i, a)`, whose *first* draw (for `a > 0`) is
//! the uniform ancestor redraw and whose remainder feeds the propagation
//! step. Slot outcomes therefore depend only on their own streams and on
//! parent values — never on how attempts interleave across shards — which
//! is what makes the alive PF shard-parallel with K-invariant output.
//!
//! The oracle here is an *independent reimplementation* of that contract:
//! a model whose acceptance is a pure function of the stream lets the test
//! replay every draw with `alive_retry_rng` directly and predict the
//! engine's evidence, posterior mean, and total attempt count bit for bit.
//! If the engine's stream discipline drifts (an extra draw, a reordered
//! draw, a cumulative counter sneaking back in), these tests fail.

use lazycow::config::{Model, RunConfig, Task};
use lazycow::heap::{CopyMode, Heap, Lazy, ShardedHeap};
use lazycow::lazy_fields;
use lazycow::models::Crbd;
use lazycow::pool::ThreadPool;
use lazycow::rng::Pcg64;
use lazycow::smc::{alive_retry_rng, run_filter, run_filter_shards, Method, SmcModel, StepCtx};
use lazycow::stats::{log_sum_exp, normalize_log_weights};

fn ctx(pool: &ThreadPool) -> StepCtx<'_> {
    StepCtx { pool, kalman: None, batch: true }
}

/// A model whose alive-PF behaviour is a pure function of the retry
/// stream: each step draws one uniform `u`; the particle dies iff
/// `u < p_die`, otherwise gains weight `ln(1 + u)` and accumulates `u`
/// into its state (chained through the heap, so retries still exercise
/// deep-copy/release on real lineages).
struct RetryModel {
    t_max: usize,
    p_die: f64,
}

#[derive(Clone)]
struct RState {
    acc: f64,
    prev: Lazy<RState>,
}
lazy_fields!(RState: prev);

impl SmcModel for RetryModel {
    type State = RState;

    fn name(&self) -> &'static str {
        "retry-oracle"
    }

    fn horizon(&self) -> usize {
        self.t_max
    }

    fn init(&self, heap: &mut Heap, _rng: &mut Pcg64) -> Lazy<RState> {
        heap.alloc(RState {
            acc: 0.0,
            prev: Lazy::NULL,
        })
    }

    fn step(
        &self,
        heap: &mut Heap,
        state: &mut Lazy<RState>,
        _t: usize,
        rng: &mut Pcg64,
        observe: bool,
    ) -> f64 {
        let u = rng.next_f64();
        let acc = heap.read(state, |s| s.acc);
        let old = *state;
        let new = heap.alloc(RState {
            acc: acc + u,
            prev: old,
        });
        heap.release(old);
        *state = new;
        if observe && u < self.p_die {
            f64::NEG_INFINITY
        } else {
            (1.0 + u).ln()
        }
    }

    fn summary(&self, heap: &mut Heap, state: &mut Lazy<RState>) -> f64 {
        heap.read(state, |s| s.acc)
    }
}

/// Replay the stream contract directly: the expected attempts, evidence,
/// and posterior mean for `RetryModel` under an alive PF with resampling
/// disabled (`ess_threshold = 0`), using the same stats primitives in the
/// same order as the engine — so the comparison can be bitwise.
fn reference_alive(seed: u64, n: usize, t_max: usize, p_die: f64) -> (usize, u64, u64) {
    let mut accs = vec![0.0f64; n];
    let mut lw = vec![0.0f64; n];
    let mut attempts = 0usize;
    for t in 1..=t_max {
        let mut new_accs = vec![0.0f64; n];
        let mut winc_out = vec![0.0f64; n];
        for i in 0..n {
            let mut attempt = 0usize;
            loop {
                let mut rng = alive_retry_rng(seed, t, i, attempt);
                let a = if attempt == 0 {
                    i
                } else {
                    rng.below(n as u64) as usize
                };
                let u = rng.next_f64();
                attempts += 1;
                attempt += 1;
                if u >= p_die {
                    new_accs[i] = accs[a] + u;
                    winc_out[i] = (1.0 + u).ln();
                    break;
                }
                assert!(attempt < 10_000, "reference bailout");
            }
        }
        accs = new_accs;
        for i in 0..n {
            lw[i] += winc_out[i];
        }
    }
    // Final evidence + posterior exactly as the engine computes them.
    let log_z = log_sum_exp(&lw) - (n as f64).ln();
    let mut w = Vec::new();
    normalize_log_weights(&lw, &mut w);
    let mut post = 0.0;
    for i in 0..n {
        post += w[i] * accs[i];
    }
    (attempts, log_z.to_bits(), post.to_bits())
}

/// The engine reproduces the independently-replayed stream contract bit
/// for bit, for K ∈ {1, 2, 4} — pinning the per-slot-stream oracle values
/// and the attempts-invariant-in-K guarantee in one shot.
#[test]
fn engine_matches_reference_stream_oracle() {
    let (seed, n, t_max, p_die) = (0xA11CE, 32, 12, 0.35);
    let model = RetryModel { t_max, p_die };
    let (want_attempts, want_lz, want_post) = reference_alive(seed, n, t_max, p_die);
    assert!(
        want_attempts > n * t_max,
        "test is vacuous unless some retries happen (got {want_attempts})"
    );
    let pool = ThreadPool::new(3);
    let mut cfg = RunConfig::for_model(Model::List, Task::Inference, CopyMode::LazySro);
    cfg.n_particles = n;
    cfg.n_steps = t_max;
    cfg.seed = seed;
    cfg.ess_threshold = 0.0; // never resample: the pure stream contract
    for k in [1usize, 2, 4] {
        for mode in CopyMode::ALL {
            let mut cfg = cfg.clone();
            cfg.mode = mode;
            let mut sh = ShardedHeap::new(mode, k);
            let r = run_filter_shards(&model, &cfg, sh.shards_mut(), &ctx(&pool), Method::Alive);
            assert_eq!(
                r.attempts, want_attempts,
                "K={k}/{mode:?}: attempts diverge from the stream contract"
            );
            assert_eq!(
                r.log_evidence.to_bits(),
                want_lz,
                "K={k}/{mode:?}: evidence diverges from the stream contract"
            );
            assert_eq!(
                r.posterior_mean.to_bits(),
                want_post,
                "K={k}/{mode:?}: posterior diverges from the stream contract"
            );
            assert_eq!(sh.live_objects(), 0, "K={k}/{mode:?} leaked");
        }
    }
}

/// Real-model coverage: CRBD under the alive PF is bitwise K-invariant
/// with exactly equal attempt counts, the population spread over all
/// shards (the v1 contract collapsed it onto shard 0), and clean shards.
#[test]
fn crbd_alive_bitwise_and_attempts_invariant_in_k() {
    let model = Crbd::synthetic(30, 2);
    let pool = ThreadPool::new(2);
    let mut cfg = RunConfig::for_model(Model::Crbd, Task::Inference, CopyMode::LazySro);
    cfg.n_particles = 64;
    cfg.n_steps = model.horizon();
    cfg.seed = 3;

    let mut baseline = Heap::new(CopyMode::LazySro);
    let base = run_filter(&model, &cfg, &mut baseline, &ctx(&pool), Method::Alive);
    assert!(base.log_evidence.is_finite());
    assert!(
        base.attempts >= 64 * model.horizon(),
        "attempt count includes retries"
    );
    assert_eq!(baseline.live_objects(), 0);

    for k in [2usize, 4] {
        let mut sh = ShardedHeap::new(CopyMode::LazySro, k);
        let r = run_filter_shards(&model, &cfg, sh.shards_mut(), &ctx(&pool), Method::Alive);
        assert_eq!(r.log_evidence.to_bits(), base.log_evidence.to_bits());
        assert_eq!(r.posterior_mean.to_bits(), base.posterior_mean.to_bits());
        assert_eq!(r.attempts, base.attempts, "K={k}: attempts not invariant");
        assert_eq!(sh.live_objects(), 0, "K={k} leaked");
        for (s, h) in sh.shards().iter().enumerate() {
            assert_eq!(
                h.metrics.total_allocs,
                h.metrics.total_frees + h.metrics.live_objects,
                "K={k}: shard {s} balance broken"
            );
            assert!(
                h.metrics.total_allocs > 0,
                "K={k}: shard {s} idle — the alive population no longer spreads"
            );
        }
    }
}

/// The 10k-attempt bailout fires deterministically — on the lowest slot,
/// at the first generation — when no particle can ever survive.
#[test]
#[should_panic(expected = "alive PF: no surviving particle after 10k attempts at t=1 (slot 0)")]
fn bailout_after_10k_attempts_is_deterministic() {
    let model = RetryModel {
        t_max: 1,
        p_die: 1.1, // u < 1.1 always: every attempt dies
    };
    let pool = ThreadPool::new(1);
    let mut cfg = RunConfig::for_model(Model::List, Task::Inference, CopyMode::LazySro);
    cfg.n_particles = 2;
    cfg.n_steps = 1;
    cfg.seed = 1;
    cfg.ess_threshold = 0.0;
    let mut heap = Heap::new(CopyMode::LazySro);
    let _ = run_filter(&model, &cfg, &mut heap, &ctx(&pool), Method::Alive);
}
