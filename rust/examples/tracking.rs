//! Multi-object tracking: ragged per-particle track arrays on the lazy
//! heap.
//!
//! Each particle owns a list of track objects; tracks untouched in a
//! generation remain shared across the whole population, tracks that are
//! updated copy on write — per-object granularity sharing that page-level
//! (fork-based) COW cannot achieve. Prints the posterior track count
//! against the simulation ground truth and the eager/lazy memory contrast.
//!
//! ```sh
//! cargo run --release --example tracking
//! ```

use lazycow::bench::human_bytes;
use lazycow::config::{Model, RunConfig, Task};
use lazycow::heap::{CopyMode, Heap};
use lazycow::models::{Mot, DATA_SEED};
use lazycow::pool::ThreadPool;
use lazycow::smc::{run_filter, Method, StepCtx};

fn main() {
    let t = 60;
    let model = Mot::synthetic(t, DATA_SEED);
    let total_points: usize = model.obs.iter().map(|o| o.len()).sum();
    println!(
        "simulated scene: {} frames, {} observed points (targets + clutter)",
        t, total_points
    );

    let pool = ThreadPool::new(0);
    let ctx = StepCtx {
        pool: &pool,
        kalman: None,
        batch: true,
    };

    println!(
        "\n{:<10} {:>10} {:>16} {:>12} {:>12}",
        "mode", "wall(s)", "E[#tracks] @ T", "peak mem", "lazy copies"
    );
    for mode in [CopyMode::Eager, CopyMode::Lazy, CopyMode::LazySro] {
        let mut cfg = RunConfig::for_model(Model::Mot, Task::Inference, mode);
        cfg.n_particles = 128;
        cfg.n_steps = t;
        let mut heap = Heap::new(mode);
        let r = run_filter(&model, &cfg, &mut heap, &ctx, Method::Bootstrap);
        println!(
            "{:<10} {:>10.3} {:>16.2} {:>12} {:>12}",
            mode.name(),
            r.wall_s,
            r.posterior_mean,
            human_bytes(r.peak_bytes as f64),
            heap.metrics.lazy_copies
        );
        assert_eq!(heap.live_objects(), 0);
    }
    println!("\ndone.");
}
