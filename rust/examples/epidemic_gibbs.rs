//! Epidemic inference: particle Gibbs on the vector-borne-disease model.
//!
//! Demonstrates the out-of-tree usage pattern the paper calls out for VBD:
//! between Gibbs iterations a single reference trajectory is deep-copied
//! **eagerly**, while within each conditional SMC sweep resampling uses
//! lazy copies. Reports per-iteration evidence and the posterior reporting
//! rate recovered from the marginalized gamma–Poisson accumulator.
//!
//! ```sh
//! cargo run --release --example epidemic_gibbs
//! ```

use lazycow::bench::human_bytes;
use lazycow::config::{Model, RunConfig, Task};
use lazycow::heap::{CopyMode, Heap};
use lazycow::models::{Vbd, DATA_SEED};
use lazycow::pool::ThreadPool;
use lazycow::smc::{run_particle_gibbs, StepCtx};

fn main() {
    let t = 120;
    let model = Vbd::synthetic(t, DATA_SEED);
    let peak_week = model
        .obs
        .iter()
        .enumerate()
        .max_by_key(|(_, c)| **c)
        .unwrap();
    println!(
        "synthetic dengue-like outbreak: {} weeks, peak {} cases in week {}",
        t, peak_week.1, peak_week.0
    );

    let pool = ThreadPool::new(0);
    let ctx = StepCtx {
        pool: &pool,
        kalman: None,
        batch: true,
    };
    let mut cfg = RunConfig::for_model(Model::Vbd, Task::Inference, CopyMode::LazySro);
    cfg.n_particles = 256;
    cfg.n_steps = t;
    cfg.pg_iterations = 4;

    let mut heap = Heap::new(CopyMode::LazySro);
    let results = run_particle_gibbs(&model, &cfg, &mut heap, &ctx);
    println!("\nparticle Gibbs ({} iterations, N={}):", results.len(), cfg.n_particles);
    for (i, r) in results.iter().enumerate() {
        println!(
            "  iter {}: log-evidence {:.2}, E[I_h + rho] = {:.3}, wall {:.2}s, peak {}",
            i,
            r.log_evidence,
            r.posterior_mean,
            r.wall_s,
            human_bytes(r.peak_bytes as f64)
        );
    }
    println!(
        "\nheap after run: {} (eager copies = the inter-iteration reference copies)",
        heap.metrics.summary()
    );
    assert!(heap.metrics.eager_copies > 0);
    assert_eq!(heap.live_objects(), 0);
    println!("done.");
}
