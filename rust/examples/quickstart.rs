//! Quickstart: the paper's Table 1 and Table 2 linked-list walkthrough on
//! the lazy heap, narrated.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use lazycow::heap::{CopyMode, Heap, Lazy};
use lazycow::lazy_fields;

/// The paper's `class Node { value:Integer; next:Node; }`.
#[derive(Clone)]
struct Node {
    value: i64,
    next: Lazy<Node>,
}
lazy_fields!(Node: next);

fn list_values(heap: &mut Heap, head: &Lazy<Node>) -> Vec<i64> {
    let mut out = Vec::new();
    let mut cur = *head;
    while !cur.is_null() {
        out.push(heap.read(&mut cur, |n| n.value));
        cur = heap.read_ptr(&mut cur, |n| n.next);
    }
    out
}

fn main() {
    let mut heap = Heap::new(CopyMode::LazySro);

    println!("== Table 1: tree-pattern lazy deep copies ==\n");
    // x1 -> y1 -> z1 with values 1, 2, 3.
    let z1 = heap.alloc(Node { value: 3, next: Lazy::NULL });
    let y1 = heap.alloc(Node { value: 2, next: z1 });
    let x1 = heap.alloc(Node { value: 1, next: y1 });
    heap.release(y1);
    heap.release(z1);
    println!("built x1->y1->z1: {:?} ({} objects)", list_values(&mut heap, &x1), heap.live_objects());

    // x2 <- deep_copy(x1): O(1) — a new label, no object copies.
    let mut x2 = heap.deep_copy(&x1);
    println!(
        "deep_copy(x1): still {} objects (copy is lazy; label {:?})",
        heap.live_objects(),
        x2.label()
    );

    // Reading never copies.
    let v = heap.read(&mut x2, |n| n.value);
    println!("read x2.value = {v}: still {} objects", heap.live_objects());

    // Writing copies exactly the written node.
    heap.mutate_root(&mut x2, |n| n.value = 10);
    println!(
        "x2.value <- 10: now {} objects (head copied on write)",
        heap.live_objects()
    );

    // Descending for write copies each node along the path (Table 1's
    // commentary) — the get-chain.
    let mut y2 = heap.get_field(&x2, |n| &mut n.next);
    heap.mutate(&mut y2, |n| n.value = 20);
    let mut z2 = heap.get_field(&y2, |n| &mut n.next);
    heap.mutate(&mut z2, |n| n.value = 30);
    println!(
        "wrote the whole copy: {} objects; x1 = {:?}, x2 = {:?}",
        heap.live_objects(),
        list_values(&mut heap, &x1),
        list_values(&mut heap, &x2)
    );
    println!("heap: {}\n", heap.metrics.summary());

    // Releasing the copy reclaims its private nodes.
    heap.release(x2);
    println!("released x2: {} objects remain", heap.live_objects());
    heap.release(x1);

    println!("\n== Table 2: cross references fall back to eager copies ==\n");
    let x1 = heap.alloc(Node { value: 1, next: Lazy::NULL });
    let mut x2 = heap.deep_copy(&x1);
    heap.mutate_root(&mut x2, |n| n.value = 2);
    // x2.next <- x1: an edge into another lineage — a cross reference.
    heap.mutate_root(&mut x2, |n| n.next = x1);
    let mut x3 = heap.deep_copy(&x2); // outside the tree pattern -> eager
    heap.mutate_root(&mut x3, |n| n.value = 3);
    let mut y3 = heap.read_ptr(&mut x3, |n| n.next);
    let printed = heap.read(&mut y3, |n| n.value);
    println!("y3 <- x3.next; print(y3.value) = {printed}   (correct: 1)");
    assert_eq!(printed, 1);
    println!("heap: {}", heap.metrics.summary());

    heap.release(x3);
    heap.release(x2);
    heap.release(x1);
    heap.sweep_memos();
    heap.deep_sweep(&[]);
    assert_eq!(heap.live_objects(), 0);
    println!("\nall objects reclaimed — done.");
}
