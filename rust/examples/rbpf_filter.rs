//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Runs the Rao–Blackwellized particle filter (RBPF) at a realistic scale
//! in all three copy configurations, with the batched Kalman generation
//! executed through the AOT-compiled XLA artifact (the L1 Pallas kernel)
//! when available. Proves the layers compose: Rust coordinator + lazy COW
//! heap ↔ PJRT runtime ↔ jax/Pallas-lowered HLO — and reproduces the
//! paper's headline contrast (lazy ≪ eager in time and peak memory, with
//! identical inference output).
//!
//! ```sh
//! make artifacts && cargo run --release --example rbpf_filter
//! ```

use lazycow::bench::human_bytes;
use lazycow::config::{Model, RunConfig, Task};
use lazycow::heap::{CopyMode, ShardedHeap};
use lazycow::models::run_model;
use lazycow::pool::ThreadPool;
use lazycow::runtime::{BatchKalman, XlaRuntime};
use lazycow::smc::StepCtx;

fn main() {
    let n = 512;
    let t = 200;

    let pool = ThreadPool::new(0);
    let rt = XlaRuntime::cpu("artifacts").expect("PJRT CPU client");
    let kalman = if rt.has_artifact("kalman3") {
        println!(
            "PJRT platform: {} — using compiled kalman3 artifact",
            rt.platform()
        );
        Some(BatchKalman::load(&rt).expect("load kalman3"))
    } else {
        println!("artifacts not built (run `make artifacts`) — CPU oracle path");
        None
    };
    let ctx = StepCtx {
        pool: &pool,
        kalman: kalman.as_ref(),
        batch: true,
    };

    println!("\nRBPF, N={n}, T={t}, bootstrap filter, resampling every step\n");
    println!(
        "{:<10} {:>12} {:>14} {:>12} {:>10} {:>10}",
        "mode", "wall(s)", "log-evidence", "peak mem", "copies", "objects@T"
    );
    let mut outputs = Vec::new();
    for mode in CopyMode::ALL {
        let mut cfg = RunConfig::for_model(Model::Rbpf, Task::Inference, mode);
        cfg.n_particles = n;
        cfg.n_steps = t;
        cfg.seed = 20200401;
        // Single shard: the serialized-heap baseline the paper measures
        // (pass more shards to exercise the sharded engine).
        let mut heap = ShardedHeap::new(mode, 1);
        let r = run_model(&cfg, &mut heap, &ctx);
        let m = heap.metrics();
        let copies = m.lazy_copies + m.eager_copies;
        let last_objs = r.series.last().map(|s| s.live_objects).unwrap_or(0);
        println!(
            "{:<10} {:>12.3} {:>14.4} {:>12} {:>10} {:>10}",
            mode.name(),
            r.wall_s,
            r.log_evidence,
            human_bytes(r.peak_bytes as f64),
            copies,
            last_objs
        );
        outputs.push(r.log_evidence);
        assert_eq!(heap.live_objects(), 0, "heap fully reclaimed");
    }

    // The paper's §4 output check: identical results in every mode.
    assert_eq!(outputs[0].to_bits(), outputs[1].to_bits());
    assert_eq!(outputs[1].to_bits(), outputs[2].to_bits());
    println!("\noutput identical across configurations ✓ (log-evidence matches bitwise)");
}
